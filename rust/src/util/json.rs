//! Minimal but complete JSON: parser, serializer, and a typed accessor
//! API. Used for the scheduler RPC protocol, checkpoint files, the
//! artifact meta contract and result payloads. (No serde offline.)

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are f64 (with i64 fast-path accessors);
/// object keys are sorted (BTreeMap) so serialization is canonical —
/// important because result payloads are compared bitwise by the
/// validator and signed by the code signer.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as u64) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed-path helpers for RPC decoding.
    pub fn str_of(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field '{key}'"))
    }

    pub fn u64_of(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("missing u64 field '{key}'"))
    }

    pub fn f64_of(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing f64 field '{key}'"))
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<Vec<i32>> for Json {
    fn from(v: Vec<i32>) -> Json {
        Json::Arr(v.into_iter().map(|x| Json::Num(x as f64)).collect())
    }
}
impl From<Vec<f64>> for Json {
    fn from(v: Vec<f64>) -> Json {
        Json::Arr(v.into_iter().map(Json::Num).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.007e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> anyhow::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek()? != c {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek()? == b']' {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b']' => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        c => anyhow::bail!("expected ',' or ']' got '{}'", c as char),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek()? == b'}' {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek()? {
                        b',' => self.i += 1,
                        b'}' => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        c => anyhow::bail!("expected ',' or '}}' got '{}'", c as char),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                anyhow::bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => anyhow::bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| anyhow::anyhow!("invalid utf8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let j = Json::obj()
            .set("name", "wu_17")
            .set("n", 42u64)
            .set("pi", 3.5)
            .set("ok", true)
            .set("xs", vec![1, 2, 3]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": null}, "x"], "c": -1.5e2}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::parse(r#""Cáceres — Mérida""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Cáceres — Mérida");
    }

    #[test]
    fn canonical_ordering() {
        let a = Json::obj().set("z", 1u64).set("a", 2u64);
        let b = Json::obj().set("a", 2u64).set("z", 1u64);
        assert_eq!(a.to_string(), b.to_string());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }
}
