//! Leveled stderr logging with a global verbosity switch. Kept tiny on
//! purpose: the hot paths must never allocate for suppressed levels, so
//! the macros check the level before formatting.

use std::sync::atomic::{AtomicU8, Ordering};

/// 0 = error, 1 = warn, 2 = info (default), 3 = debug, 4 = trace.
static LEVEL: AtomicU8 = AtomicU8::new(2);

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn emit(tag: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{tag}] {args}");
}

#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => { $crate::util::log::emit("ERROR", format_args!($($t)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        if $crate::util::log::level() >= 1 { $crate::util::log::emit("WARN ", format_args!($($t)*)) }
    };
}
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        if $crate::util::log::level() >= 2 { $crate::util::log::emit("INFO ", format_args!($($t)*)) }
    };
}
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::util::log::level() >= 3 { $crate::util::log::emit("DEBUG", format_args!($($t)*)) }
    };
}
#[macro_export]
macro_rules! log_trace {
    ($($t:tt)*) => {
        if $crate::util::log::level() >= 4 { $crate::util::log::emit("TRACE", format_args!($($t)*)) }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn level_roundtrip() {
        let old = super::level();
        super::set_level(4);
        assert_eq!(super::level(), 4);
        log_debug!("visible at level 4: {}", 42);
        log_trace!("visible at level 4: {}", 43);
        super::set_level(3);
        log_trace!("suppressed at level 3: {}", 44);
        super::set_level(old);
    }
}
