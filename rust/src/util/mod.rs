//! In-repo substrates: RNG, JSON, statistics, bench harness, property
//! testing, logging. The offline build environment provides no external
//! crates for these, so they are implemented here (see DESIGN.md §2).

pub mod bench;
pub mod codec;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;

/// Format a duration in seconds as `1h02m03s` / `42.0s` / `123ms`.
pub fn fmt_secs(secs: f64) -> String {
    if secs < 0.001 {
        format!("{:.0}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.0}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.1}s")
    } else if secs < 7200.0 {
        format!("{:.0}m{:02.0}s", (secs / 60.0).floor(), secs % 60.0)
    } else {
        format!("{:.1}h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(0.000001), "1us");
        assert_eq!(fmt_secs(0.5), "500ms");
        assert_eq!(fmt_secs(42.0), "42.0s");
        assert_eq!(fmt_secs(3600.0), "60m00s");
        assert_eq!(fmt_secs(86400.0), "24.0h");
    }
}
