//! Deterministic pseudo-random numbers: splitmix64 seeding +
//! xoshiro256** generation, plus the distributions the churn and GP
//! models need (uniform, exponential, Poisson, normal, log-normal,
//! beta-like availability fractions).
//!
//! Every simulation component takes an explicit `Rng` so campaigns are
//! reproducible from a single seed; streams are forked with
//! [`Rng::fork`] to decorrelate subsystems.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (splitmix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for a subsystem or worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Snapshot the exact xoshiro256** state (checkpointing): a
    /// generator restored with [`Rng::from_state`] continues the
    /// stream bit-identically — required for BOINC-style
    /// resume-after-churn to match an uninterrupted run.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Restore from a [`Rng::state`] snapshot. The all-zero state is
    /// invalid for xoshiro (it is a fixed point); it is mapped to the
    /// seed-0 state so corrupt checkpoints degrade deterministically
    /// instead of emitting a constant stream.
    pub fn from_state(s: [u64; 4]) -> Rng {
        if s == [0; 4] {
            return Rng::new(0);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) (Lemire-ish via widening multiply).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Uniform float in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with given mean (inverse-CDF).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Poisson count with given mean (Knuth for small, normal approx large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let n = mean + self.normal() * mean.sqrt();
            return n.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Standard normal (Box–Muller, one value per call).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with given median and sigma (of the underlying normal).
    pub fn log_normal(&mut self, median: f64, sigma: f64) -> f64 {
        median * (self.normal() * sigma).exp()
    }

    /// A [0,1] "availability fraction" with given mean, Kumaraswamy-like:
    /// convenient smooth unimodal distribution used for on_frac/active_frac
    /// (Anderson & Fedak report means; shape is not critical).
    pub fn fraction(&mut self, mean: f64) -> f64 {
        let m = mean.clamp(0.05, 0.95);
        // mix toward the mean: beta(2, 2*(1-m)/m)-ish via two uniforms
        let u = self.f64();
        let v = self.f64();
        let x = (u + v) / 2.0; // triangular around 0.5
        let shifted = x + (m - 0.5);
        shifted.clamp(0.0, 1.0)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_continues_stream_exactly() {
        let mut a = Rng::new(42);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_state_degrades_deterministically() {
        let mut a = Rng::from_state([0; 4]);
        let mut b = Rng::new(0);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(42);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.exp(5.0)).sum::<f64>() / n as f64;
        assert!((m - 5.0).abs() < 0.2, "mean {m}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
        // large-mean path
        let m2: f64 = (0..5_000).map(|_| r.poisson(200.0) as f64).sum::<f64>() / 5_000.0;
        assert!((m2 - 200.0).abs() < 2.0, "mean {m2}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fraction_in_bounds_and_biased() {
        let mut r = Rng::new(19);
        let m: f64 = (0..10_000).map(|_| r.fraction(0.8)).sum::<f64>() / 10_000.0;
        assert!(m > 0.7 && m < 0.9, "mean {m}");
        for _ in 0..1000 {
            let x = r.fraction(0.3);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
