//! Small statistics toolkit: summary stats, percentiles, and an online
//! accumulator used by the metrics layer and the bench harness.

/// Online mean/variance (Welford) with min/max.
#[derive(Clone, Debug, Default)]
pub struct Accum {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, sum: 0.0 }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Percentile via linear interpolation on a sorted copy (q in [0,1]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Least-squares slope+intercept; used for trend checks in churn traces.
pub fn linreg(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (slope, my - slope * mx * if den == 0.0 { 0.0 } else { 1.0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut a = Accum::new();
        for &x in &xs {
            a.add(x);
        }
        assert!((a.mean() - mean(&xs)).abs() < 1e-12);
        assert!((a.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 10.0);
        assert_eq!(a.count(), 5);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn linreg_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 2.0).collect();
        let (slope, icept) = linreg(&xs, &ys);
        assert!((slope - 3.0).abs() < 1e-9);
        assert!((icept - 2.0).abs() < 1e-9);
    }
}
