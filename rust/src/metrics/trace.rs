//! WU-lifecycle tracing: a bounded ring buffer of typed events keyed on
//! **virtual time** (the DES clock — never the wall clock, so the
//! repo's `wall-clock` lint rule holds for every caller).
//!
//! # Event vocabulary
//!
//! The normal life of a workunit reads, in order:
//!
//! | event        | recorded by                  | meaning                                          |
//! |--------------|------------------------------|--------------------------------------------------|
//! | `generated`  | `ServerCore::submit_wu`      | WU entered the queue (vt 0 — campaign setup)     |
//! | `dispatched` | `ServerCore::request_work`   | a result replica was handed to a host            |
//! | `executed`   | `report_success/report_error`| the host reported back (ok = success RPC)        |
//! | `expired`    | `ServerCore::tick`           | a replica's deadline passed with no reply        |
//! | `late_report`| `report_success`             | success arrived for an already-terminal replica (wasted volunteer work) |
//! | `validated`  | transitioner (quorum check)  | replica judged against the quorum (valid flag)   |
//! | `assimilated`| transitioner                 | canonical payload banked into `assimilated()`    |
//!
//! Island campaigns append the migration-exchange / barrier events:
//!
//! | event                  | recorded by                 | meaning                                       |
//! |------------------------|-----------------------------|-----------------------------------------------|
//! | `banked`               | `MigrationExchange` (bank)  | epoch WU's checkpoint + emigrants banked      |
//! | `emigrant_quarantined` | `MigrationExchange` (bank)  | an emigrant failed re-verification            |
//! | `released`             | exchange barrier open       | next-epoch WU released with immigrant set     |
//! | `boosted`              | exchange straggler race     | extra replica raced against a straggler       |
//! | `cancelled`            | exchange dead-chain sweep   | WU cancelled (its chain was written off)      |
//! | `barrier_timeout`      | exchange timeout sweep      | barrier gave up waiting on a deme's epoch     |
//! | `host_quarantined`     | `ServerCore::request_work`  | work refused: host inside reliability probation |
//!
//! # Causality ids
//!
//! Every record carries two optional causality ids: the host id (for
//! per-host timelines: `Trace::for_host`) and the `(deme, epoch)`
//! coordinate (for per-barrier timelines: `Trace::for_coord`). Records
//! are additionally stamped with a monotonically increasing `seq` so
//! same-virtual-time events keep a total order.
//!
//! # Payload neutrality
//!
//! Recording is strictly write-only bookkeeping behind `&self`: no code
//! in the payload path ever reads the ring back, and the buffer is
//! disabled (capacity 0) unless explicitly enabled, so tracing cannot
//! change a canonical payload byte (`tests/observability.rs` proves
//! this end-to-end).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use crate::util::json::Json;

/// A typed WU-lifecycle / barrier event. See the module docs for the
/// full vocabulary and who records what.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    Generated { wu: u64 },
    Dispatched { wu: u64, result: u64 },
    Executed { wu: u64, result: u64, ok: bool },
    Expired { wu: u64, result: u64 },
    LateReport { wu: u64, result: u64 },
    Validated { wu: u64, result: u64, valid: bool },
    Assimilated { wu: u64 },
    Banked { wu: u64, emigrants: usize },
    EmigrantQuarantined { wu: u64 },
    Released { wu: u64, immigrants: usize },
    Boosted { wu: u64 },
    Cancelled { wu: u64 },
    BarrierTimeout { wu: u64 },
    HostQuarantined,
}

impl TraceEvent {
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Generated { .. } => "generated",
            TraceEvent::Dispatched { .. } => "dispatched",
            TraceEvent::Executed { .. } => "executed",
            TraceEvent::Expired { .. } => "expired",
            TraceEvent::LateReport { .. } => "late_report",
            TraceEvent::Validated { .. } => "validated",
            TraceEvent::Assimilated { .. } => "assimilated",
            TraceEvent::Banked { .. } => "banked",
            TraceEvent::EmigrantQuarantined { .. } => "emigrant_quarantined",
            TraceEvent::Released { .. } => "released",
            TraceEvent::Boosted { .. } => "boosted",
            TraceEvent::Cancelled { .. } => "cancelled",
            TraceEvent::BarrierTimeout { .. } => "barrier_timeout",
            TraceEvent::HostQuarantined => "host_quarantined",
        }
    }

    fn fields(&self, j: Json) -> Json {
        match *self {
            TraceEvent::Generated { wu }
            | TraceEvent::Assimilated { wu }
            | TraceEvent::EmigrantQuarantined { wu }
            | TraceEvent::Boosted { wu }
            | TraceEvent::Cancelled { wu }
            | TraceEvent::BarrierTimeout { wu } => j.set("wu", wu),
            TraceEvent::Dispatched { wu, result }
            | TraceEvent::Expired { wu, result }
            | TraceEvent::LateReport { wu, result } => j.set("wu", wu).set("result", result),
            TraceEvent::Executed { wu, result, ok } => j.set("wu", wu).set("result", result).set("ok", ok),
            TraceEvent::Validated { wu, result, valid } => j.set("wu", wu).set("result", result).set("valid", valid),
            TraceEvent::Banked { wu, emigrants } => j.set("wu", wu).set("emigrants", emigrants),
            TraceEvent::Released { wu, immigrants } => j.set("wu", wu).set("immigrants", immigrants),
            TraceEvent::HostQuarantined => j,
        }
    }
}

/// One ring-buffer record: virtual time + total-order seq + causality
/// ids + the typed event.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// DES virtual time (seconds) the event happened at.
    pub vt: f64,
    /// Monotonic sequence number (total order within a run).
    pub seq: u64,
    /// Per-host causality id (None for server-internal events).
    pub host: Option<u64>,
    /// Per-(deme, epoch) causality id (None outside island campaigns).
    pub coord: Option<(usize, usize)>,
    pub event: TraceEvent,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj().set("vt", self.vt).set("seq", self.seq).set("event", self.event.kind());
        if let Some(h) = self.host {
            j = j.set("host", h);
        }
        if let Some((d, e)) = self.coord {
            j = j.set("deme", d).set("epoch", e);
        }
        self.event.fields(j)
    }
}

#[derive(Default)]
struct Ring {
    cap: usize,
    next_seq: u64,
    dropped: u64,
    buf: VecDeque<TraceRecord>,
}

/// Bounded, thread-safe trace ring. Disabled (capacity 0) by default;
/// `record` is a cheap early-return until `enable` is called. Interior
/// mutability (`&self`) so shared-ref stages like the exchange's
/// bank pass can record.
#[derive(Default)]
pub struct Trace {
    enabled: AtomicBool,
    inner: Mutex<Ring>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Turn tracing on with a ring capacity (oldest records are dropped
    /// — and counted — once the ring is full).
    pub fn enable(&self, capacity: usize) {
        let mut r = self.inner.lock().unwrap();
        r.cap = capacity;
        self.enabled.store(capacity > 0, Ordering::Relaxed);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one event at virtual time `vt`. No-op while disabled.
    pub fn record(&self, vt: f64, host: Option<u64>, coord: Option<(usize, usize)>, event: TraceEvent) {
        if !self.is_enabled() {
            return;
        }
        let mut r = self.inner.lock().unwrap();
        let seq = r.next_seq;
        r.next_seq += 1;
        if r.buf.len() == r.cap {
            r.buf.pop_front();
            r.dropped += 1;
        }
        r.buf.push_back(TraceRecord { vt, seq, host, coord, event });
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total records evicted from the full ring.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Total records ever recorded (including since-evicted ones).
    pub fn recorded(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Snapshot of the ring contents, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.inner.lock().unwrap().buf.iter().cloned().collect()
    }

    /// Per-host timeline (causality id filter).
    pub fn for_host(&self, host: u64) -> Vec<TraceRecord> {
        self.records().into_iter().filter(|r| r.host == Some(host)).collect()
    }

    /// Per-(deme, epoch) timeline (causality id filter).
    pub fn for_coord(&self, deme: usize, epoch: usize) -> Vec<TraceRecord> {
        self.records().into_iter().filter(|r| r.coord == Some((deme, epoch))).collect()
    }

    /// Canonical JSON summary: counts plus the most recent `keep`
    /// records (the ring tail).
    pub fn to_json(&self, keep: usize) -> Json {
        let recs = self.records();
        let tail = recs.len().saturating_sub(keep);
        Json::obj()
            .set("enabled", self.is_enabled())
            .set("recorded", self.recorded())
            .set("dropped", self.dropped())
            .set("recent", Json::Arr(recs[tail..].iter().map(TraceRecord::to_json).collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_ring_records_nothing() {
        let t = Trace::new();
        t.record(1.0, Some(1), None, TraceEvent::Generated { wu: 7 });
        assert!(t.is_empty());
        assert_eq!(t.recorded(), 0);
    }

    #[test]
    fn ring_bounds_and_drop_count() {
        let t = Trace::new();
        t.enable(3);
        for i in 0..5u64 {
            t.record(i as f64, None, None, TraceEvent::Generated { wu: i });
        }
        assert_eq!(t.len(), 3);
        assert_eq!(t.dropped(), 2);
        assert_eq!(t.recorded(), 5);
        let recs = t.records();
        assert_eq!(recs[0].seq, 2, "oldest two evicted");
        assert_eq!(recs[2].vt, 4.0);
    }

    #[test]
    fn causality_filters() {
        let t = Trace::new();
        t.enable(16);
        t.record(1.0, Some(3), Some((0, 1)), TraceEvent::Dispatched { wu: 9, result: 1 });
        t.record(2.0, Some(4), Some((1, 1)), TraceEvent::Dispatched { wu: 10, result: 2 });
        t.record(3.0, Some(3), Some((0, 1)), TraceEvent::Executed { wu: 9, result: 1, ok: true });
        assert_eq!(t.for_host(3).len(), 2);
        assert_eq!(t.for_coord(0, 1).len(), 2);
        assert_eq!(t.for_coord(1, 1).len(), 1);
        assert_eq!(t.for_host(99).len(), 0);
    }

    #[test]
    fn json_has_vocabulary_kinds() {
        let t = Trace::new();
        t.enable(8);
        t.record(5.0, Some(1), Some((0, 0)), TraceEvent::Banked { wu: 2, emigrants: 3 });
        t.record(6.0, None, Some((0, 1)), TraceEvent::Released { wu: 4, immigrants: 2 });
        let j = t.to_json(8);
        let s = j.to_string();
        assert!(s.contains("\"event\":\"banked\""));
        assert!(s.contains("\"immigrants\":2"));
        assert!(s.contains("\"deme\":0"));
        assert_eq!(j.get("recorded").unwrap().as_u64().unwrap(), 2);
    }
}
