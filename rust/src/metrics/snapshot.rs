//! Fleet snapshot: one canonical JSON document (`schema: vgp.fleet.v1`)
//! capturing the observable state of a run — the typed metrics
//! registry, the host table, island-campaign progress, migration
//! exchange stats and the trace-ring tail — at a single virtual-time
//! instant.
//!
//! The snapshot is the contract between producers (`vgp sim
//! --metrics-out`, the serve-mode `Stats` RPC, campaign reports) and
//! the payload-neutral consumer (`vgp dashboard`): everything the
//! dashboard renders comes from this document, never from live server
//! state, so observing a run cannot perturb it. Rendering is canonical
//! (BTreeMap-ordered object keys via [`Json`]) and schema-validated on
//! read, mirroring `util::bench::validate_bench_json`.

use crate::boinc::exchange::{ExchangeStats, MigrationExchange};
use crate::boinc::server::ServerCore;
use crate::util::json::Json;

use super::MetricsSnapshot;

/// Schema tag stamped into (and required of) every fleet snapshot.
pub const SCHEMA: &str = "vgp.fleet.v1";

/// How many trace-ring tail records ride along in a snapshot.
const TRACE_KEEP: usize = 64;

/// One row of the dashboard's host table: identity, capacity, and the
/// reliability state the scheduler acts on.
#[derive(Clone, Debug, PartialEq)]
pub struct HostView {
    pub id: u64,
    pub name: String,
    pub flops: f64,
    pub ncpus: u64,
    pub in_flight: u64,
    pub valid: u64,
    pub errors: u64,
    /// consecutive-error streak (the reliability gate's input)
    pub streak: u64,
    /// true when the scheduler would refuse this host work right now
    /// (same predicate as `ServerCore::request_work`'s gate)
    pub quarantined: bool,
    pub credit: f64,
}

impl HostView {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("id", self.id)
            .set("name", self.name.as_str())
            .set("flops", self.flops)
            .set("ncpus", self.ncpus)
            .set("in_flight", self.in_flight)
            .set("valid", self.valid)
            .set("errors", self.errors)
            .set("streak", self.streak)
            .set("quarantined", self.quarantined)
            .set("credit", self.credit)
    }

    fn from_json(j: &Json) -> anyhow::Result<HostView> {
        Ok(HostView {
            id: j.u64_of("id")?,
            name: j.str_of("name")?.to_string(),
            flops: j.f64_of("flops")?,
            ncpus: j.u64_of("ncpus")?,
            in_flight: j.u64_of("in_flight")?,
            valid: j.u64_of("valid")?,
            errors: j.u64_of("errors")?,
            streak: j.u64_of("streak")?,
            quarantined: j.get("quarantined").and_then(Json::as_bool).ok_or_else(|| {
                anyhow::anyhow!("host {}: missing bool 'quarantined'", j.u64_of("id").unwrap_or(0))
            })?,
            credit: j.f64_of("credit")?,
        })
    }
}

/// Island-campaign progress: the `[deme][epoch]` state grid plus the
/// exchange's observable counters.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignView {
    pub demes: usize,
    pub epochs: usize,
    /// per-cell state: `held | released | banked | dead`
    pub cells: Vec<Vec<String>>,
    pub stats: ExchangeStats,
}

const CELL_STATES: &[&str] = &["held", "released", "banked", "dead"];

fn stats_to_json(s: &ExchangeStats) -> Json {
    Json::obj()
        .set("banked", s.banked)
        .set("released", s.released)
        .set("immigrants_delivered", s.immigrants_delivered)
        .set("empty_releases", s.empty_releases)
        .set("timeouts", s.timeouts)
        .set("cancelled", s.cancelled)
        .set("boosted", s.boosted)
        .set("quarantined", s.quarantined)
}

fn stats_from_json(j: &Json) -> anyhow::Result<ExchangeStats> {
    Ok(ExchangeStats {
        banked: j.u64_of("banked")?,
        released: j.u64_of("released")?,
        immigrants_delivered: j.u64_of("immigrants_delivered")?,
        empty_releases: j.u64_of("empty_releases")?,
        timeouts: j.u64_of("timeouts")?,
        cancelled: j.u64_of("cancelled")?,
        boosted: j.u64_of("boosted")?,
        quarantined: j.u64_of("quarantined")?,
    })
}

impl CampaignView {
    /// Count of cells in `state` for one deme row.
    pub fn count(&self, deme: usize, state: &str) -> usize {
        self.cells[deme].iter().filter(|s| s == &state).count()
    }

    fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .cells
            .iter()
            .map(|row| Json::Arr(row.iter().map(|s| Json::from(s.as_str())).collect()))
            .collect();
        Json::obj()
            .set("demes", self.demes)
            .set("epochs", self.epochs)
            .set("cells", Json::Arr(rows))
            .set("stats", stats_to_json(&self.stats))
    }

    fn from_json(j: &Json) -> anyhow::Result<CampaignView> {
        let demes = j.u64_of("demes")? as usize;
        let epochs = j.u64_of("epochs")? as usize;
        let rows = j
            .get("cells")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("campaign: missing 'cells' array"))?;
        anyhow::ensure!(rows.len() == demes, "campaign: {} cell rows, demes = {demes}", rows.len());
        let mut cells = Vec::with_capacity(demes);
        for (d, row) in rows.iter().enumerate() {
            let row = row.as_arr().ok_or_else(|| anyhow::anyhow!("campaign: cells[{d}] is not an array"))?;
            anyhow::ensure!(row.len() == epochs, "campaign: deme {d} has {} cells, epochs = {epochs}", row.len());
            let mut out = Vec::with_capacity(epochs);
            for (e, cell) in row.iter().enumerate() {
                let s = cell.as_str().ok_or_else(|| anyhow::anyhow!("campaign: cells[{d}][{e}] is not a string"))?;
                anyhow::ensure!(CELL_STATES.contains(&s), "campaign: cells[{d}][{e}]: unknown state '{s}'");
                out.push(s.to_string());
            }
            cells.push(out);
        }
        let stats = j
            .get("stats")
            .ok_or_else(|| anyhow::anyhow!("campaign: missing 'stats'"))
            .and_then(stats_from_json)?;
        Ok(CampaignView { demes, epochs, cells, stats })
    }
}

/// The whole-fleet snapshot document. Producers build it with
/// [`FleetSnapshot::from_parts`]; the dashboard rebuilds it from disk
/// (or the wire) with [`FleetSnapshot::from_json`].
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    /// DES virtual time the snapshot was taken at (seconds).
    pub virtual_time: f64,
    pub metrics: MetricsSnapshot,
    pub hosts: Vec<HostView>,
    /// present only for island campaigns
    pub campaign: Option<CampaignView>,
    /// trace section (`Trace::to_json`): counts + ring tail
    pub trace: Json,
}

impl FleetSnapshot {
    /// Capture the observable state of a run. Read-only over every
    /// input — taking a snapshot cannot perturb the run.
    pub fn from_parts(core: &ServerCore, exchange: Option<&MigrationExchange>, now: f64) -> FleetSnapshot {
        let hosts = core
            .db
            .hosts
            .values()
            .map(|h| HostView {
                id: h.id,
                name: h.name.clone(),
                flops: h.flops,
                ncpus: h.ncpus as u64,
                in_flight: h.in_flight as u64,
                valid: h.valid_results,
                errors: h.error_results,
                streak: h.consecutive_errors,
                // same predicate as the scheduler's reliability gate
                quarantined: h.consecutive_errors >= core.cfg.reliability_error_threshold
                    && (now < h.last_error_at + core.cfg.reliability_probation || h.in_flight > 0),
                credit: h.credit,
            })
            .collect();
        let campaign = exchange.map(|ex| {
            let (demes, epochs) = ex.dims();
            let cells = (0..demes)
                .map(|d| (0..epochs).map(|e| ex.epoch_state(d, e).to_string()).collect())
                .collect();
            CampaignView { demes, epochs, cells, stats: ex.stats.clone() }
        });
        FleetSnapshot {
            virtual_time: now,
            metrics: core.metrics.snapshot(),
            hosts,
            campaign,
            trace: core.trace.to_json(TRACE_KEEP),
        }
    }

    /// Canonical JSON rendering (byte-stable for a given state).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("schema", SCHEMA)
            .set("virtual_time", self.virtual_time)
            .set("metrics", self.metrics.to_json())
            .set("hosts", Json::Arr(self.hosts.iter().map(HostView::to_json).collect()))
            .set("trace", self.trace.clone());
        if let Some(c) = &self.campaign {
            j = j.set("campaign", c.to_json());
        }
        j
    }

    /// Parse and validate a snapshot document. Every schema violation
    /// is an error — the dashboard never renders half-valid data.
    pub fn from_json(j: &Json) -> anyhow::Result<FleetSnapshot> {
        let schema = j.str_of("schema")?;
        anyhow::ensure!(schema == SCHEMA, "unsupported snapshot schema '{schema}' (want {SCHEMA})");
        let vt = j.f64_of("virtual_time")?;
        anyhow::ensure!(vt.is_finite() && vt >= 0.0, "virtual_time must be finite and >= 0 (got {vt})");
        let metrics = j
            .get("metrics")
            .ok_or_else(|| anyhow::anyhow!("missing 'metrics'"))
            .and_then(MetricsSnapshot::from_json)?;
        let hosts = j
            .get("hosts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing 'hosts' array"))?
            .iter()
            .map(HostView::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let campaign = match j.get("campaign") {
            Some(c) => Some(CampaignView::from_json(c)?),
            None => None,
        };
        let trace = j.get("trace").cloned().ok_or_else(|| anyhow::anyhow!("missing 'trace' section"))?;
        trace.u64_of("recorded").map_err(|_| anyhow::anyhow!("trace section missing 'recorded'"))?;
        trace.u64_of("dropped").map_err(|_| anyhow::anyhow!("trace section missing 'dropped'"))?;
        Ok(FleetSnapshot { virtual_time: vt, metrics, hosts, campaign, trace })
    }

    /// Write the snapshot to `path` (canonical JSON + trailing newline).
    pub fn write(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| anyhow::anyhow!("writing snapshot {path}: {e}"))
    }
}

/// Read + schema-validate a snapshot file (the CI smoke job's check,
/// mirroring `util::bench::validate_bench_json`).
pub fn validate_snapshot_json(path: &str) -> anyhow::Result<FleetSnapshot> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    FleetSnapshot::from_json(&Json::parse(&text)?).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boinc::db::HostRow;
    use crate::boinc::server::ServerConfig;
    use crate::boinc::workunit::WorkUnit;
    use crate::metrics::Counter;

    fn host(id_hint: &str, flops: f64) -> HostRow {
        HostRow {
            id: 0,
            name: id_hint.into(),
            city: "Badajoz".into(),
            flops,
            ncpus: 2,
            on_frac: 1.0,
            active_frac: 1.0,
            registered_at: 0.0,
            last_heartbeat: 0.0,
            error_results: 0,
            valid_results: 0,
            consecutive_errors: 0,
            last_error_at: 0.0,
            in_flight: 0,
            credit: 0.0,
        }
    }

    fn snap_from_small_run() -> FleetSnapshot {
        let mut core = ServerCore::new(ServerConfig::default());
        core.trace.enable(32);
        let h = core.register_host(host("h0", 1e9));
        core.register_host(host("h1", 2e9));
        core.submit_wu(WorkUnit::new(0, "wu", Json::obj(), 1e9));
        let (rid, _, _) = core.request_work(h, 0.0).unwrap();
        core.report_success(rid, 100.0, 90.0, Json::obj().set("hits", 3u64));
        FleetSnapshot::from_parts(&core, None, 100.0)
    }

    #[test]
    fn roundtrip_is_canonical_and_validates() {
        let snap = snap_from_small_run();
        assert_eq!(snap.hosts.len(), 2);
        assert_eq!(snap.metrics.counter(Counter::ResultDispatched), 1);
        assert!(snap.trace.u64_of("recorded").unwrap() >= 2, "generated + dispatched at least");
        let j = snap.to_json();
        let back = FleetSnapshot::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string(), "canonical roundtrip");
        assert_eq!(back.hosts, snap.hosts);
    }

    #[test]
    fn quarantine_flag_mirrors_scheduler_gate() {
        let cfg = ServerConfig { reliability_error_threshold: 2, reliability_probation: 1000.0, ..Default::default() };
        let mut core = ServerCore::new(cfg);
        let h = core.register_host(host("bad", 1e9));
        for _ in 0..2 {
            core.submit_wu(WorkUnit::new(0, "wu", Json::obj(), 1e9));
        }
        for _ in 0..2 {
            let (rid, _, _) = core.request_work(h, 0.0).unwrap();
            core.report_error(rid, 1.0);
        }
        let snap = FleetSnapshot::from_parts(&core, None, 1.0);
        assert!(snap.hosts[0].quarantined, "inside probation window");
        assert_eq!(snap.hosts[0].streak, 2);
        let later = FleetSnapshot::from_parts(&core, None, 5000.0);
        assert!(!later.hosts[0].quarantined, "probation elapsed, probe allowed");
    }

    #[test]
    fn schema_violations_are_rejected() {
        let good = snap_from_small_run().to_json();
        // wrong schema tag
        let bad = Json::parse(&good.to_string()).unwrap().set("schema", "vgp.fleet.v0");
        assert!(FleetSnapshot::from_json(&bad).is_err());
        // missing sections
        for key in ["metrics", "hosts", "trace", "virtual_time"] {
            let mut without = Json::obj();
            if let Json::Obj(map) = &good {
                for (k, v) in map {
                    if k != key {
                        without = without.set(k.as_str(), v.clone());
                    }
                }
            }
            assert!(FleetSnapshot::from_json(&without).is_err(), "must reject missing '{key}'");
        }
        // campaign cell with an unknown state string
        let with_campaign = Json::parse(&good.to_string()).unwrap().set(
            "campaign",
            Json::obj()
                .set("demes", 1u64)
                .set("epochs", 1u64)
                .set("cells", Json::Arr(vec![Json::Arr(vec![Json::from("limbo")])]))
                .set("stats", stats_to_json(&ExchangeStats::default())),
        );
        assert!(FleetSnapshot::from_json(&with_campaign).is_err());
    }

    #[test]
    fn campaign_counts() {
        let c = CampaignView {
            demes: 2,
            epochs: 3,
            cells: vec![
                vec!["banked".into(), "released".into(), "held".into()],
                vec!["banked".into(), "banked".into(), "dead".into()],
            ],
            stats: ExchangeStats::default(),
        };
        assert_eq!(c.count(0, "banked"), 1);
        assert_eq!(c.count(1, "banked"), 2);
        assert_eq!(c.count(1, "dead"), 1);
        let j = c.to_json();
        let back = CampaignView::from_json(&j).unwrap();
        assert_eq!(back, c);
    }
}
