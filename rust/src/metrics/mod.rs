//! Metrics: named counters/accumulators, CSV export, and an ASCII
//! time-series plotter (used for the Fig-2 host-churn trace).

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::Accum;

/// Thread-safe metrics registry. One per server / simulation run.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    accums: Mutex<BTreeMap<String, Accum>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, n: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += n;
    }

    pub fn observe(&self, name: &str, value: f64) {
        let mut a = self.accums.lock().unwrap();
        a.entry(name.to_string()).or_default().add(value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn summary(&self, name: &str) -> Option<(u64, f64, f64)> {
        let a = self.accums.lock().unwrap();
        a.get(name).map(|acc| (acc.count(), acc.mean(), acc.std()))
    }

    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        for (k, a) in self.accums.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k}: n={} mean={:.4} std={:.4} min={:.4} max={:.4}\n",
                a.count(),
                a.mean(),
                a.std(),
                a.min(),
                a.max()
            ));
        }
        out
    }
}

/// Write rows as CSV (headers + f64 rows). Returns the rendered string
/// and optionally writes it to `path`.
pub fn to_csv(headers: &[&str], rows: &[Vec<f64>], path: Option<&str>) -> anyhow::Result<String> {
    let mut s = String::new();
    s.push_str(&headers.join(","));
    s.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        s.push_str(&cells.join(","));
        s.push('\n');
    }
    if let Some(p) = path {
        std::fs::write(p, &s)?;
    }
    Ok(s)
}

/// ASCII plot of a single series (e.g. active hosts per day, Fig 2).
pub fn ascii_plot(title: &str, xs: &[f64], ys: &[f64], height: usize) -> String {
    assert_eq!(xs.len(), ys.len());
    let mut out = format!("{title}\n");
    if ys.is_empty() {
        return out;
    }
    let ymax = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1e-9);
    let ymin = ys.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0);
    let width = ys.len();
    for level in (0..height).rev() {
        let thr = ymin + (ymax - ymin) * (level as f64 + 0.5) / height as f64;
        let mut line = String::with_capacity(width + 10);
        line.push_str(&format!("{:>8.1} |", ymin + (ymax - ymin) * (level as f64 + 1.0) / height as f64));
        for &y in ys {
            line.push(if y >= thr { '#' } else { ' ' });
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}x: {:.0} .. {:.0}  ({} points)\n",
        "", xs.first().unwrap(), xs.last().unwrap(), width
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_accums() {
        let m = Metrics::new();
        m.inc("wu.dispatched");
        m.add("wu.dispatched", 4);
        m.observe("rpc.latency", 1.0);
        m.observe("rpc.latency", 3.0);
        assert_eq!(m.counter("wu.dispatched"), 5);
        let (n, mean, _) = m.summary("rpc.latency").unwrap();
        assert_eq!(n, 2);
        assert!((mean - 2.0).abs() < 1e-12);
        assert!(m.dump().contains("wu.dispatched = 5"));
    }

    #[test]
    fn csv_renders() {
        let s = to_csv(&["day", "hosts"], &[vec![1.0, 10.0], vec![2.0, 12.0]], None).unwrap();
        assert_eq!(s, "day,hosts\n1,10\n2,12\n");
    }

    #[test]
    fn ascii_plot_shape() {
        let xs: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x.sin().abs() * 10.0).collect();
        let p = ascii_plot("churn", &xs, &ys, 8);
        assert!(p.lines().count() >= 10);
        assert!(p.contains('#'));
    }
}
