//! Fleet observability: a typed metrics registry (counters, gauges,
//! fixed-bucket histograms — all with static names and label sets), a
//! Prometheus text-exposition exporter, a canonical JSON snapshot, plus
//! the CSV export and ASCII time-series plotter used for the Fig-2
//! host-churn trace.
//!
//! Every metric is declared at compile time in the tables below; there
//! are no string-keyed entries, so a typo'd metric name is a compile
//! error, the snapshot schema is closed, and the Prometheus label sets
//! (`vgp_results_total{event="valid"}` …) are static. Reads are typed
//! too: [`Metrics::get`] takes a [`Counter`] variant — the old
//! string-keyed `counter("result.valid")` accessor and the free-text
//! `dump()` are gone (the `legacy-metrics` lint rule keeps them out),
//! with [`Counter::from_name`] remaining as the one name→variant
//! bridge for external tooling such as the dashboard's
//! `--require-nonzero`.
//!
//! The registry is payload-neutral by construction: nothing in the
//! WU-payload path reads a metric back, and recording takes interior
//! mutability (`&Metrics`), so enabling or disabling observability
//! cannot perturb canonical payload bytes (proven end-to-end by
//! `tests/observability.rs`).

pub mod dashboard;
pub mod snapshot;
pub mod trace;

use std::fmt::Write as _;
use std::sync::Mutex;

use crate::util::json::Json;

macro_rules! metric_enum {
    ($(#[$doc:meta])* $ty:ident { $($variant:ident => $name:literal, $family:literal, $label:literal;)* }) => {
        $(#[$doc])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        pub enum $ty {
            $($variant,)*
        }

        impl $ty {
            pub const ALL: &'static [$ty] = &[$($ty::$variant,)*];

            /// Canonical dotted name (snapshot / dump / `counter()` key).
            pub fn name(self) -> &'static str {
                match self {
                    $($ty::$variant => $name,)*
                }
            }

            /// Prometheus family + static label value. An empty label
            /// means the family has no `event` dimension.
            pub fn family(self) -> (&'static str, &'static str) {
                match self {
                    $($ty::$variant => ($family, $label),)*
                }
            }

            fn index(self) -> usize {
                self as usize
            }

            pub fn from_name(name: &str) -> Option<$ty> {
                Self::ALL.iter().copied().find(|m| m.name() == name)
            }
        }
    };
}

metric_enum! {
    /// Monotonic event counters. Names mirror the BOINC server-daemon
    /// vocabulary (transitioner / validator / assimilator events).
    Counter {
        WuSubmitted => "wu.submitted", "vgp_workunits_total", "submitted";
        WuReleased => "wu.released", "vgp_workunits_total", "released";
        WuBoosted => "wu.boosted", "vgp_workunits_total", "boosted";
        WuCancelled => "wu.cancelled", "vgp_workunits_total", "cancelled";
        WuAssimilated => "wu.assimilated", "vgp_workunits_total", "assimilated";
        WuTooManyErrors => "wu.too_many_errors", "vgp_workunits_total", "too_many_errors";
        WuTooManyTotal => "wu.too_many_total", "vgp_workunits_total", "too_many_total";
        HostRegistered => "host.registered", "vgp_host_rpcs_total", "registered";
        HostHeartbeat => "host.heartbeat", "vgp_host_rpcs_total", "heartbeat";
        HostUnreliableRefusal => "host.unreliable_refusal", "vgp_host_rpcs_total", "unreliable_refusal";
        UnknownHostRefusal => "host.unknown_refusal", "vgp_host_rpcs_total", "unknown_refusal";
        ResultDispatched => "result.dispatched", "vgp_results_total", "dispatched";
        ResultSuccess => "result.success", "vgp_results_total", "success";
        ResultClientError => "result.client_error", "vgp_results_total", "client_error";
        ResultNoReply => "result.no_reply", "vgp_results_total", "no_reply";
        ResultValid => "result.valid", "vgp_results_total", "valid";
        ResultInvalid => "result.invalid", "vgp_results_total", "invalid";
        ResultReissued => "result.reissued", "vgp_results_total", "reissued";
        ResultDidntNeed => "result.didnt_need", "vgp_results_total", "didnt_need";
        ResultLateSuccess => "result.late_success", "vgp_results_total", "late_success";
        ExchangeVerifyOk => "exchange.verify.ok", "vgp_exchange_total", "verify_ok";
        ExchangeVerifyRejected => "exchange.verify.rejected", "vgp_exchange_total", "verify_rejected";
        ExchangeCancelled => "exchange.cancelled", "vgp_exchange_total", "cancelled";
        ExchangeBoosted => "exchange.boosted", "vgp_exchange_total", "boosted";
        ExchangeTimeout => "exchange.timeout", "vgp_exchange_total", "timeout";
        ExchangeReleased => "exchange.released", "vgp_exchange_total", "released";
        SimExecutorFailure => "sim.executor_failure", "vgp_sim_total", "executor_failure";
        VerifyOk => "verify.ok", "vgp_verify_total", "ok";
        VerifyRejected => "verify.rejected", "vgp_verify_total", "rejected";
        VerifyWarnings => "verify.warnings", "vgp_verify_total", "warnings";
    }
}

metric_enum! {
    /// Last-write-wins instantaneous values.
    Gauge {
        HostsAttached => "hosts.attached", "vgp_hosts_attached", "";
        ResultsInFlight => "results.in_flight", "vgp_results_in_flight", "";
        VirtualTime => "sim.virtual_time", "vgp_virtual_time_seconds", "";
    }
}

metric_enum! {
    /// Fixed-bucket histograms (bucket edges are compile-time consts).
    Hist {
        WuTurnaround => "wu.turnaround_secs", "vgp_wu_turnaround_seconds", "";
        WuCpu => "wu.cpu_secs", "vgp_wu_cpu_seconds", "";
        ExchangeImmigrants => "exchange.immigrants", "vgp_exchange_immigrants", "";
    }
}

impl Hist {
    /// Upper bucket edges (virtual seconds / counts); an implicit +Inf
    /// bucket follows the last edge.
    pub fn buckets(self) -> &'static [f64] {
        match self {
            Hist::WuTurnaround => &[60.0, 300.0, 900.0, 3600.0, 14400.0, 86400.0],
            Hist::WuCpu => &[10.0, 60.0, 600.0, 3600.0, 14400.0, 86400.0],
            Hist::ExchangeImmigrants => &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0],
        }
    }
}

/// `# HELP` strings, one per Prometheus family (families are shared by
/// several counters via their static `event` label).
const FAMILY_HELP: &[(&str, &str)] = &[
    ("vgp_workunits_total", "workunit lifecycle events by kind"),
    ("vgp_host_rpcs_total", "host scheduler-RPC events by kind"),
    ("vgp_results_total", "result lifecycle events by kind"),
    ("vgp_exchange_total", "island migration-exchange events by kind"),
    ("vgp_sim_total", "simulation harness events by kind"),
    ("vgp_verify_total", "spec/tape verification outcomes by kind"),
    ("vgp_hosts_attached", "hosts currently attached to the fleet"),
    ("vgp_results_in_flight", "results dispatched and not yet reported"),
    ("vgp_virtual_time_seconds", "current DES virtual time"),
    ("vgp_wu_turnaround_seconds", "dispatch-to-report turnaround (virtual time)"),
    ("vgp_wu_cpu_seconds", "reported CPU time per result"),
    ("vgp_exchange_immigrants", "immigrants delivered per epoch release"),
];

fn family_help(family: &str) -> &'static str {
    FAMILY_HELP.iter().find(|(f, _)| *f == family).map(|(_, h)| *h).unwrap_or("")
}

#[derive(Clone, Debug, Default)]
struct HistData {
    counts: Vec<u64>, // buckets().len() + 1 (+Inf)
    sum: f64,
    count: u64,
}

#[derive(Default)]
struct State {
    counters: Vec<u64>,
    gauges: Vec<f64>,
    hists: Vec<HistData>,
}

/// Thread-safe typed metrics registry. One per server / simulation run.
pub struct Metrics {
    state: Mutex<State>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        let state = State {
            counters: vec![0; Counter::ALL.len()],
            gauges: vec![0.0; Gauge::ALL.len()],
            hists: Hist::ALL
                .iter()
                .map(|h| HistData { counts: vec![0; h.buckets().len() + 1], sum: 0.0, count: 0 })
                .collect(),
        };
        Metrics { state: Mutex::new(state) }
    }

    pub fn inc(&self, c: Counter) {
        self.add(c, 1);
    }

    pub fn add(&self, c: Counter, n: u64) {
        self.state.lock().unwrap().counters[c.index()] += n;
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.state.lock().unwrap().counters[c.index()]
    }

    pub fn set_gauge(&self, g: Gauge, v: f64) {
        self.state.lock().unwrap().gauges[g.index()] = v;
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.state.lock().unwrap().gauges[g.index()]
    }

    pub fn observe(&self, h: Hist, v: f64) {
        let mut s = self.state.lock().unwrap();
        let d = &mut s.hists[h.index()];
        let edges = h.buckets();
        let slot = edges.iter().position(|&e| v <= e).unwrap_or(edges.len());
        d.counts[slot] += 1;
        d.sum += v;
        d.count += 1;
    }

    /// Structured point-in-time snapshot of every registered metric.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let s = self.state.lock().unwrap();
        MetricsSnapshot {
            counters: Counter::ALL.iter().map(|&c| (c, s.counters[c.index()])).collect(),
            gauges: Gauge::ALL.iter().map(|&g| (g, s.gauges[g.index()])).collect(),
            hists: Hist::ALL
                .iter()
                .map(|&h| {
                    let d = &s.hists[h.index()];
                    (
                        h,
                        HistSnapshot {
                            buckets: h.buckets(),
                            counts: d.counts.clone(),
                            sum: d.sum,
                            count: d.count,
                        },
                    )
                })
                .collect(),
        }
    }

    /// Prometheus text exposition (version 0.0.4).
    pub fn prometheus(&self) -> String {
        self.snapshot().prometheus()
    }
}

/// Typed snapshot of the registry: the structured replacement for
/// string-parsing `dump()` output.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub counters: Vec<(Counter, u64)>,
    pub gauges: Vec<(Gauge, f64)>,
    pub hists: Vec<(Hist, HistSnapshot)>,
}

#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: &'static [f64],
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

impl MetricsSnapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters.iter().find(|(k, _)| *k == c).map(|(_, v)| *v).unwrap_or(0)
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges.iter().find(|(k, _)| *k == g).map(|(_, v)| *v).unwrap_or(0.0)
    }

    /// Canonical JSON (BTreeMap-ordered object keys, so the rendering
    /// is byte-stable for a given state).
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (c, v) in &self.counters {
            counters = counters.set(c.name(), *v);
        }
        let mut gauges = Json::obj();
        for (g, v) in &self.gauges {
            gauges = gauges.set(g.name(), *v);
        }
        let mut hists = Json::obj();
        for (h, d) in &self.hists {
            hists = hists.set(
                h.name(),
                Json::obj()
                    .set("buckets", d.buckets.to_vec())
                    .set("counts", Json::Arr(d.counts.iter().map(|&n| Json::from(n)).collect()))
                    .set("sum", d.sum)
                    .set("count", d.count),
            );
        }
        Json::obj().set("counters", counters).set("gauges", gauges).set("histograms", hists)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<MetricsSnapshot> {
        let counters = j.get("counters").ok_or_else(|| anyhow::anyhow!("missing 'counters'"))?;
        let gauges = j.get("gauges").ok_or_else(|| anyhow::anyhow!("missing 'gauges'"))?;
        let hists = j.get("histograms").ok_or_else(|| anyhow::anyhow!("missing 'histograms'"))?;
        let mut snap = MetricsSnapshot { counters: Vec::new(), gauges: Vec::new(), hists: Vec::new() };
        for &c in Counter::ALL {
            let v = counters.u64_of(c.name())?;
            snap.counters.push((c, v));
        }
        for &g in Gauge::ALL {
            let v = gauges.f64_of(g.name())?;
            snap.gauges.push((g, v));
        }
        for &h in Hist::ALL {
            let d = hists.get(h.name()).ok_or_else(|| anyhow::anyhow!("missing histogram '{}'", h.name()))?;
            let counts: Vec<u64> = d
                .get("counts")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("histogram '{}' missing counts", h.name()))?
                .iter()
                .filter_map(Json::as_u64)
                .collect();
            if counts.len() != h.buckets().len() + 1 {
                anyhow::bail!(
                    "histogram '{}' has {} count slots, schema requires {}",
                    h.name(),
                    counts.len(),
                    h.buckets().len() + 1
                );
            }
            snap.hists.push((
                h,
                HistSnapshot { buckets: h.buckets(), counts, sum: d.f64_of("sum")?, count: d.u64_of("count")? },
            ));
        }
        Ok(snap)
    }

    /// Human-readable dump (one `name = value` line per metric).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (c, v) in &self.counters {
            let _ = writeln!(out, "{} = {v}", c.name());
        }
        for (g, v) in &self.gauges {
            let _ = writeln!(out, "{} = {v}", g.name());
        }
        for (h, d) in &self.hists {
            let _ = writeln!(out, "{}: n={} mean={:.4} sum={:.4}", h.name(), d.count, d.mean(), d.sum);
        }
        out
    }

    /// Prometheus text exposition (version 0.0.4): counters grouped
    /// into families with static `event` labels, gauges bare, and
    /// histograms as cumulative `_bucket{le=…}` series.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = "";
        for (c, v) in &self.counters {
            let (family, label) = c.family();
            if family != last_family {
                let _ = writeln!(out, "# HELP {family} {}", family_help(family));
                let _ = writeln!(out, "# TYPE {family} counter");
                last_family = family;
            }
            let _ = writeln!(out, "{family}{{event=\"{label}\"}} {v}");
        }
        for (g, v) in &self.gauges {
            let (family, _) = g.family();
            let _ = writeln!(out, "# HELP {family} {}", family_help(family));
            let _ = writeln!(out, "# TYPE {family} gauge");
            let _ = writeln!(out, "{family} {v}");
        }
        for (h, d) in &self.hists {
            let (family, _) = h.family();
            let _ = writeln!(out, "# HELP {family} {}", family_help(family));
            let _ = writeln!(out, "# TYPE {family} histogram");
            let mut cum = 0u64;
            for (i, edge) in d.buckets.iter().enumerate() {
                cum += d.counts[i];
                let _ = writeln!(out, "{family}_bucket{{le=\"{edge}\"}} {cum}");
            }
            cum += d.counts[d.buckets.len()];
            let _ = writeln!(out, "{family}_bucket{{le=\"+Inf\"}} {cum}");
            let _ = writeln!(out, "{family}_sum {}", d.sum);
            let _ = writeln!(out, "{family}_count {}", d.count);
        }
        out
    }
}

/// Write rows as CSV (headers + f64 rows). Returns the rendered string
/// and optionally writes it to `path`.
pub fn to_csv(headers: &[&str], rows: &[Vec<f64>], path: Option<&str>) -> anyhow::Result<String> {
    let mut s = String::new();
    s.push_str(&headers.join(","));
    s.push('\n');
    for row in rows {
        let cells: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        s.push_str(&cells.join(","));
        s.push('\n');
    }
    if let Some(p) = path {
        std::fs::write(p, &s)?;
    }
    Ok(s)
}

/// ASCII plot of a single series (e.g. active hosts per day, Fig 2).
pub fn ascii_plot(title: &str, xs: &[f64], ys: &[f64], height: usize) -> String {
    assert_eq!(xs.len(), ys.len());
    let mut out = format!("{title}\n");
    if ys.is_empty() {
        return out;
    }
    let ymax = ys.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(1e-9);
    let ymin = ys.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0);
    let width = ys.len();
    for level in (0..height).rev() {
        let thr = ymin + (ymax - ymin) * (level as f64 + 0.5) / height as f64;
        let mut line = String::with_capacity(width + 10);
        line.push_str(&format!("{:>8.1} |", ymin + (ymax - ymin) * (level as f64 + 1.0) / height as f64));
        for &y in ys {
            line.push(if y >= thr { '#' } else { ' ' });
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10}x: {:.0} .. {:.0}  ({} points)\n",
        "", xs.first().unwrap(), xs.last().unwrap(), width
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_counter_reads() {
        let m = Metrics::new();
        m.inc(Counter::ResultDispatched);
        m.add(Counter::ResultDispatched, 4);
        assert_eq!(m.get(Counter::ResultDispatched), 5);
        // the one remaining name→variant bridge (external tooling)
        assert_eq!(Counter::from_name("result.dispatched"), Some(Counter::ResultDispatched));
        assert_eq!(Counter::from_name("no.such.metric"), None);
        assert!(m.snapshot().render().contains("result.dispatched = 5"));
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        m.set_gauge(Gauge::HostsAttached, 3.0);
        m.set_gauge(Gauge::HostsAttached, 7.0);
        assert_eq!(m.gauge(Gauge::HostsAttached), 7.0);
    }

    #[test]
    fn histogram_buckets_fill() {
        let m = Metrics::new();
        m.observe(Hist::WuTurnaround, 30.0); // <= 60
        m.observe(Hist::WuTurnaround, 500.0); // <= 900
        m.observe(Hist::WuTurnaround, 1e9); // +Inf
        let snap = m.snapshot();
        let (_, d) = snap.hists.iter().find(|(h, _)| *h == Hist::WuTurnaround).unwrap();
        assert_eq!(d.count, 3);
        assert_eq!(d.counts[0], 1);
        assert_eq!(d.counts[2], 1);
        assert_eq!(*d.counts.last().unwrap(), 1);
        assert!((d.sum - (30.0 + 500.0 + 1e9)).abs() < 1e-3);
    }

    #[test]
    fn snapshot_json_roundtrip_is_canonical() {
        let m = Metrics::new();
        m.inc(Counter::WuSubmitted);
        m.set_gauge(Gauge::VirtualTime, 120.5);
        m.observe(Hist::WuCpu, 42.0);
        let snap = m.snapshot();
        let j = snap.to_json();
        let back = MetricsSnapshot::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string());
        assert_eq!(back.counter(Counter::WuSubmitted), 1);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let m = Metrics::new();
        m.inc(Counter::ResultValid);
        m.observe(Hist::WuTurnaround, 100.0);
        let text = m.prometheus();
        assert!(text.contains("# TYPE vgp_results_total counter"));
        assert!(text.contains("vgp_results_total{event=\"valid\"} 1"));
        assert!(text.contains("# TYPE vgp_wu_turnaround_seconds histogram"));
        assert!(text.contains("vgp_wu_turnaround_seconds_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("vgp_wu_turnaround_seconds_count 1"));
        // every family referenced by a metric has HELP text
        for &c in Counter::ALL {
            assert!(!family_help(c.family().0).is_empty(), "{}", c.name());
        }
    }

    #[test]
    fn metric_names_are_unique() {
        for (i, &a) in Counter::ALL.iter().enumerate() {
            for &b in &Counter::ALL[i + 1..] {
                assert_ne!(a.name(), b.name());
            }
        }
    }

    #[test]
    fn csv_renders() {
        let s = to_csv(&["day", "hosts"], &[vec![1.0, 10.0], vec![2.0, 12.0]], None).unwrap();
        assert_eq!(s, "day,hosts\n1,10\n2,12\n");
    }

    #[test]
    fn ascii_plot_shape() {
        let xs: Vec<f64> = (0..30).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x.sin().abs() * 10.0).collect();
        let p = ascii_plot("churn", &xs, &ys, 8);
        assert!(p.lines().count() >= 10);
        assert!(p.contains('#'));
    }
}
