//! Payload-neutral ASCII fleet dashboard.
//!
//! Renders a [`FleetSnapshot`] document — never live server state — so
//! observing a run cannot perturb it. `vgp dashboard --from fleet.json`
//! prints something like:
//!
//! ```text
//! vgp fleet @ vt 86400s — 8 hosts (6 attached), 4 in flight
//! == hosts ==
//! | id | name | gflops | cores | in-flight | valid | errors | streak | state       |
//! |----|------|--------|-------|-----------|-------|--------|--------|-------------|
//! | 1  | h0   | 1.2    | 2     | 1         | 41    | 0      | 0      | ok          |
//! | 2  | h1   | 0.8    | 1     | 0         | 12    | 9      | 5      | quarantined |
//! == campaign 2 demes x 8 epochs (B banked, R released, . held, X dead) ==
//! | deme | progress | banked | released | held | dead |
//! |------|----------|--------|----------|------|------|
//! | 0    | BBBBR... | 4      | 1        | 3    | 0    |
//! | 1    | BBBR.... | 3      | 1        | 4    | 0    |
//! == exchange ==
//! | banked | released | immigrants | empty | timeouts | cancelled | boosted | quarantined |
//! ...
//! ```
//!
//! followed by the nonzero counters, histogram summaries and the trace
//! tail (canonical JSON, one record per line).
//!
//! This module is also the crate's one sanctioned stdout surface: the
//! `raw-print` lint rule bans bare `println!`/`eprintln!` everywhere
//! else in `src/`, so report-style output funnels through [`emit`].

use super::snapshot::FleetSnapshot;
use super::{Counter, Gauge};
use crate::util::bench::{BenchRecord, Table};
use crate::util::json::Json;

/// Print one line to stdout. The single sanctioned raw-print site for
/// report output (see the `raw-print` lint rule).
pub fn emit(line: &str) {
    println!("{line}");
}

/// Render the full fleet view from a snapshot.
pub fn render(snap: &FleetSnapshot) -> String {
    let mut out = String::new();
    let attached = snap.metrics.gauge(Gauge::HostsAttached);
    let in_flight = snap.metrics.gauge(Gauge::ResultsInFlight);
    out.push_str(&format!(
        "vgp fleet @ vt {}s — {} hosts ({attached} attached), {in_flight} in flight\n",
        snap.virtual_time,
        snap.hosts.len()
    ));

    out.push_str("== hosts ==\n");
    if snap.hosts.is_empty() {
        out.push_str("(none)\n");
    } else {
        let mut t = Table::new(&["id", "name", "gflops", "cores", "in-flight", "valid", "errors", "streak", "state"]);
        for h in &snap.hosts {
            t.row(&[
                h.id.to_string(),
                h.name.clone(),
                format!("{:.1}", h.flops / 1e9),
                h.ncpus.to_string(),
                h.in_flight.to_string(),
                h.valid.to_string(),
                h.errors.to_string(),
                h.streak.to_string(),
                if h.quarantined { "quarantined".to_string() } else { "ok".to_string() },
            ]);
        }
        out.push_str(&t.render());
    }

    if let Some(c) = &snap.campaign {
        out.push_str(&format!(
            "== campaign {} demes x {} epochs (B banked, R released, . held, X dead) ==\n",
            c.demes, c.epochs
        ));
        let mut t = Table::new(&["deme", "progress", "banked", "released", "held", "dead"]);
        for d in 0..c.demes {
            let progress: String = c.cells[d]
                .iter()
                .map(|s| match s.as_str() {
                    "banked" => 'B',
                    "released" => 'R',
                    "dead" => 'X',
                    _ => '.',
                })
                .collect();
            t.row(&[
                d.to_string(),
                progress,
                c.count(d, "banked").to_string(),
                c.count(d, "released").to_string(),
                c.count(d, "held").to_string(),
                c.count(d, "dead").to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push_str("== exchange ==\n");
        let s = &c.stats;
        let mut t = Table::new(&[
            "banked",
            "released",
            "immigrants",
            "empty",
            "timeouts",
            "cancelled",
            "boosted",
            "quarantined",
        ]);
        t.row(&[
            s.banked.to_string(),
            s.released.to_string(),
            s.immigrants_delivered.to_string(),
            s.empty_releases.to_string(),
            s.timeouts.to_string(),
            s.cancelled.to_string(),
            s.boosted.to_string(),
            s.quarantined.to_string(),
        ]);
        out.push_str(&t.render());
    }

    out.push_str("== counters (nonzero) ==\n");
    let mut any = false;
    for (c, v) in &snap.metrics.counters {
        if *v > 0 {
            out.push_str(&format!("{} = {v}\n", c.name()));
            any = true;
        }
    }
    if !any {
        out.push_str("(none)\n");
    }

    out.push_str("== histograms ==\n");
    for (h, d) in &snap.metrics.hists {
        out.push_str(&format!("{}: n={} mean={:.3} sum={:.3}\n", h.name(), d.count, d.mean(), d.sum));
    }

    out.push_str("== trace ==\n");
    let recorded = snap.trace.u64_of("recorded").unwrap_or(0);
    let dropped = snap.trace.u64_of("dropped").unwrap_or(0);
    out.push_str(&format!("recorded {recorded}, dropped {dropped}\n"));
    if let Some(recent) = snap.trace.get("recent").and_then(Json::as_arr) {
        for r in recent {
            out.push_str(&format!("  {r}\n"));
        }
    }
    out
}

/// Re-export the append-only perf trajectory (`BENCH_hotpath.json`) as
/// metrics rows — the dashboard's bench panel.
pub fn render_bench(path: &str) -> anyhow::Result<String> {
    let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let parsed = Json::parse(&text)?;
    let entries = parsed.as_arr().ok_or_else(|| anyhow::anyhow!("{path}: top level must be a JSON array"))?;
    let mut t = Table::new(&["pr", "kernel", "threads", "scheduler", "lanes", "evals/s"]);
    for e in entries {
        let r = BenchRecord::from_json(e)?;
        t.row(&[
            r.pr,
            r.kernel,
            r.threads.to_string(),
            r.scheduler,
            r.lanes.to_string(),
            format!("{:.3e}", r.evals_per_sec),
        ]);
    }
    Ok(format!("== bench trajectory ({} entries) ==\n{}", entries.len(), t.render()))
}

/// Assert the named counters are nonzero in the snapshot (CI smoke
/// check: a campaign that dispatched nothing produced a vacuous run).
pub fn require_nonzero(snap: &FleetSnapshot, names: &[&str]) -> anyhow::Result<()> {
    for name in names {
        let c = Counter::from_name(name).ok_or_else(|| anyhow::anyhow!("unknown counter '{name}'"))?;
        anyhow::ensure!(snap.metrics.counter(c) > 0, "counter '{name}' is zero in snapshot");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boinc::exchange::ExchangeStats;
    use crate::metrics::snapshot::{CampaignView, HostView};
    use crate::metrics::Metrics;

    fn synthetic_snapshot() -> FleetSnapshot {
        let m = Metrics::new();
        m.add(Counter::ResultDispatched, 9);
        m.inc(Counter::ResultValid);
        m.set_gauge(Gauge::HostsAttached, 2.0);
        m.observe(crate::metrics::Hist::WuTurnaround, 120.0);
        FleetSnapshot {
            virtual_time: 3600.0,
            metrics: m.snapshot(),
            hosts: vec![
                HostView {
                    id: 1,
                    name: "h0".into(),
                    flops: 1.2e9,
                    ncpus: 2,
                    in_flight: 1,
                    valid: 41,
                    errors: 0,
                    streak: 0,
                    quarantined: false,
                    credit: 10.0,
                },
                HostView {
                    id: 2,
                    name: "h1".into(),
                    flops: 8e8,
                    ncpus: 1,
                    in_flight: 0,
                    valid: 12,
                    errors: 9,
                    streak: 5,
                    quarantined: true,
                    credit: 3.0,
                },
            ],
            campaign: Some(CampaignView {
                demes: 2,
                epochs: 4,
                cells: vec![
                    vec!["banked".into(), "banked".into(), "released".into(), "held".into()],
                    vec!["banked".into(), "released".into(), "held".into(), "dead".into()],
                ],
                stats: ExchangeStats { banked: 3, released: 2, immigrants_delivered: 5, ..Default::default() },
            }),
            trace: Json::obj()
                .set("enabled", true)
                .set("recorded", 12u64)
                .set("dropped", 2u64)
                .set("recent", Json::Arr(vec![Json::obj().set("vt", 10.0).set("seq", 0u64).set("event", "banked")])),
        }
    }

    #[test]
    fn render_covers_all_views() {
        let text = render(&synthetic_snapshot());
        // host table with reliability state
        assert!(text.contains("== hosts =="));
        assert!(text.contains("quarantined"), "host state column");
        assert!(text.contains("| 2"), "second host row");
        // campaign progress grid
        assert!(text.contains("== campaign 2 demes x 4 epochs"));
        assert!(text.contains("BBR."), "deme 0 progress string");
        assert!(text.contains("BR.X"), "deme 1 progress string");
        // exchange stats
        assert!(text.contains("== exchange =="));
        assert!(text.contains("immigrants"));
        // counters / histograms / trace tail
        assert!(text.contains("result.dispatched = 9"));
        assert!(text.contains("wu.turnaround_secs: n=1"));
        assert!(text.contains("recorded 12, dropped 2"));
        assert!(text.contains("\"event\":\"banked\""));
    }

    #[test]
    fn nonzero_gate() {
        let snap = synthetic_snapshot();
        assert!(require_nonzero(&snap, &["result.dispatched", "result.valid"]).is_ok());
        let err = require_nonzero(&snap, &["wu.released"]).unwrap_err().to_string();
        assert!(err.contains("wu.released"), "{err}");
        assert!(require_nonzero(&snap, &["no.such.counter"]).is_err());
    }

    #[test]
    fn bench_panel_renders_trajectory() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
        let text = render_bench(path).unwrap();
        assert!(text.contains("== bench trajectory ("));
        assert!(text.contains("| pr"), "table header");
        assert!(text.lines().count() >= 5);
    }
}
