//! `vgp` — the leader binary: serve a project over TCP, run a worker,
//! execute campaigns on the simulator, plot churn.
//!
//! ```text
//! vgp sim --table 1|2|3                # regenerate a paper table (DES)
//! vgp sim --problem mux11 --runs 50 --hosts 20 --pool volunteer --ncpus 4
//! vgp sim --config campaign.ini        # [campaign]/[pool] INI file
//! vgp sim --demes 4 --epochs 4 --epoch-gens 10 --topology ring
//!                                      # island-model campaign (real GP
//!                                      # execution + server migration)
//! vgp sim --demes 4 --adaptive-migration --boost-replicas \
//!         --deme-sizes 600,500,400,300 --island-path artifact
//!                                      # adaptive island campaign:
//!                                      # per-deme migration rate from
//!                                      # banked fitness deltas,
//!                                      # straggler replica racing,
//!                                      # heterogeneous demes, epochs
//!                                      # evaluated through the AOT
//!                                      # artifact (Method 2)
//! vgp sim ... --pipeline               # drive the DES through the
//!                                      # multi-daemon pipeline (same
//!                                      # bytes; differential-tested)
//! vgp serve --runs 8 --problem mux6 --threads 4   # TCP server campaign
//! vgp serve --demes 4 --epochs 3 --port 9400      # island campaign
//!                                      # over TCP (fixed port; default
//!                                      # --port 0 = ephemeral)
//! vgp worker --addr 127.0.0.1:PORT     # attach a worker (native eval,
//!                                      # runs both WU kinds)
//! vgp churn --days 30                  # Fig-2 style churn trace
//! vgp churn --scenario flashcrowd      # shaped fleet regime
//! ```
//!
//! `--threads N` fans each WU's fitness evaluation across N cores
//! (gp::eval batch pool; payloads stay bit-identical), `--ncpus N`
//! gives every simulated host N cores, each computing one queued WU
//! (the DES per-core task model). `--scenario steady|diurnal|
//! flashcrowd|outage|ephemeral` (on `sim` and `churn`; INI key
//! `[pool] scenario`) shapes the sampled fleet's arrival/lifetime
//! regime — see [`vgp::churn::Scenario`].
//!
//! Performance knobs (all bit-identical — pure throughput):
//! `--eval-lanes 1|2|4|8` sets the boolean kernel's SIMD lane-block
//! width (u64 words per block; default 4 = 256-bit), `--reg-lanes
//! 1|2|4|8` the regression kernel's f32 lane-block width (default 8 =
//! 256-bit), `--schedule static|sorted|steal` picks the eval fan-out
//! policy (size-sorted or work-stealing schedules tame skewed
//! tree-walk populations like ant/interest-point).
//!
//! `vgp lint` runs the repo determinism lint (see [`vgp::lint`]) over
//! the crate sources and exits non-zero on findings — the same scan
//! that gates CI's `static-analysis` job.
//!
//! Observability (see [`vgp::metrics`]): `--metrics-out FILE` on
//! `sim`/`serve` writes a canonical fleet snapshot (schema
//! `vgp.fleet.v1`), `--trace N` turns on the WU-lifecycle trace ring
//! (N records, virtual-time keyed, payload-neutral), and
//! `vgp dashboard --from FILE` renders the ASCII fleet view. `-v`/`-q`
//! (repeatable) raise/lower the stderr log level on every subcommand.
//!
//! Crash recovery (see [`vgp::boinc::wal`]): `--wal FILE` on
//! `sim`/`serve` appends every server event to a sha256-chained
//! write-ahead log; restarting `vgp serve --wal FILE` replays the log
//! to the exact pre-crash state before accepting new connections.

#![deny(unsafe_code)]

use vgp::boinc::daemon::Service;
use vgp::boinc::exchange::MigrationExchange;
use vgp::boinc::net::{serve_service, Connection, Worker};
use vgp::boinc::server::{ServerConfig, ServerCore};
use vgp::churn::{churn_trace, sample_pool, PoolParams, Scenario, FIG1_CITIES_MUX11, FIG1_CITIES_MUX20};
use vgp::config::{Args, Config};
use vgp::coordinator::{
    exec, simulate_campaign, simulate_island_campaign, Campaign, IslandCampaign, IslandReport,
};
use vgp::gp::eval::Schedule;
use vgp::gp::islands::Topology;
use vgp::gp::problems::ProblemKind;
use vgp::metrics::dashboard::emit;
use vgp::metrics::snapshot::validate_snapshot_json;
use vgp::metrics::{ascii_plot, dashboard};
use vgp::sim::queue::QueueKind;
use vgp::sim::SimConfig;
use vgp::util::bench::Table;
use vgp::util::json::Json;
use vgp::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    // uniform log-level routing: default info, -v/-vv louder, -q/-qq
    // quieter, on every subcommand
    vgp::util::log::set_level(args.log_level());
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "churn" => cmd_churn(&args),
        "dashboard" => cmd_dashboard(&args),
        "lint" => cmd_lint(&args),
        _ => {
            emit("usage: vgp <sim|serve|worker|churn|dashboard|lint> [-v|-q] [--options]");
            emit("  vgp sim --table 1|2|3   reproduce a paper table");
            emit("  vgp sim --demes 4 --epochs 4 --epoch-gens 10   island-model campaign");
            emit("  vgp sim ... --trace 4096 --metrics-out fleet.json   write a fleet snapshot");
            emit("  vgp dashboard --from fleet.json   render the ASCII fleet view");
            emit("  vgp lint                run the repo determinism lint");
            0
        }
    };
    std::process::exit(code);
}

fn pool_from(kind: &str, hosts: usize, ncpus: u32, scenario: &str) -> PoolParams {
    let pool = match kind {
        "volunteer" => PoolParams::volunteer(hosts),
        "virtual" => PoolParams::virtualized_lab(hosts),
        _ => PoolParams::lab(hosts),
    };
    let scenario = Scenario::parse(scenario).unwrap_or_else(|| {
        vgp::log_error!(
            "unknown scenario '{scenario}' (steady|diurnal|flashcrowd|outage|ephemeral)"
        );
        std::process::exit(2);
    });
    pool.with_ncpus(ncpus).with_scenario(scenario)
}

fn pool_of(args: &Args, hosts: usize) -> PoolParams {
    pool_from(
        args.opt_str("pool", "lab"),
        hosts,
        args.opt_u64("ncpus", 1) as u32,
        args.opt_str("scenario", "steady"),
    )
}

/// `--flag` or `--flag true|1|yes|on` (the Args parser eats a bare
/// following value as the option's argument, so accept both shapes).
fn bool_flag(args: &Args, name: &str) -> bool {
    args.has_flag(name) || args.opt(name).map(|v| matches!(v, "true" | "1" | "yes" | "on")).unwrap_or(false)
}

/// A bad island-campaign flag exits with a curated message, never a
/// panic backtrace.
fn exit_invalid_campaign(e: anyhow::Error) -> ! {
    vgp::log_error!("invalid island campaign: {e:#}");
    std::process::exit(2);
}

/// One source of truth for the island-campaign flags shared by
/// `vgp sim --demes` and `vgp serve --demes`.
fn island_campaign_from_args(args: &Args, name: &str, problem: ProblemKind) -> IslandCampaign {
    // clamp to 1 so `--demes 0` degrades to a single-deme campaign
    // instead of tripping the IslandCampaign invariant assert
    let mut c = IslandCampaign::new(
        name,
        problem,
        args.opt_u64("demes", 4).max(1) as usize,
        args.opt_u64("epochs", 4).max(1) as usize,
        args.opt_u64("epoch-gens", 10).max(1) as usize,
        args.opt_u64("population", 500).max(1) as usize,
    );
    c.migration_k = args.opt_u64("migration-k", 2) as usize;
    c.topology = Topology::parse(args.opt_str("topology", "ring")).expect("topology");
    c.migration_timeout = args.opt_f64("migration-timeout", c.migration_timeout);
    c.seed = args.opt_u64("seed", 1);
    c.threads = args.opt_u64("threads", 1).max(1) as usize;
    c.eval_lanes = eval_lanes_of(args);
    c.reg_lanes = reg_lanes_of(args);
    c.schedule = schedule_of(args);
    // island extensions: evaluation path, adaptive migration,
    // heterogeneous deme sizes, straggler replica boosting
    c.path = exec::ExecPath::parse(args.opt_str("island-path", "native"))
        .unwrap_or_else(|e| exit_invalid_campaign(e));
    c.adaptive_migration = bool_flag(args, "adaptive-migration");
    c.boost_replicas = bool_flag(args, "boost-replicas");
    if let Some(sizes) = args.opt("deme-sizes") {
        c.deme_sizes =
            vgp::coordinator::parse_deme_sizes(sizes).unwrap_or_else(|e| exit_invalid_campaign(e));
    }
    if let Err(e) = c.validate() {
        exit_invalid_campaign(e);
    }
    c
}

/// `--eval-lanes N` — must be one of [`vgp::gp::tape::LANE_WIDTHS`];
/// anything else exits with a curated message (no silent rounding).
fn eval_lanes_of(args: &Args) -> usize {
    strict_lanes(args, "eval-lanes", vgp::gp::tape::DEFAULT_LANES)
}

/// `--reg-lanes N` — same strict contract as `--eval-lanes`.
fn reg_lanes_of(args: &Args) -> usize {
    strict_lanes(args, "reg-lanes", vgp::gp::tape::DEFAULT_REG_LANES)
}

fn strict_lanes(args: &Args, flag: &str, default: usize) -> usize {
    vgp::gp::tape::parse_lanes(args.opt_u64(flag, default as u64) as usize).unwrap_or_else(|e| {
        vgp::log_error!("invalid --{flag}: {e:#}");
        std::process::exit(2);
    })
}

/// `--schedule static|sorted|steal`.
fn schedule_of(args: &Args) -> Schedule {
    Schedule::parse(args.opt_str("schedule", "static")).expect("schedule")
}

/// `--trace N` — WU-lifecycle trace ring capacity (0 = off). The trace
/// keys on virtual time and is payload-neutral: enabling it never
/// changes a campaign byte (proven by `tests/observability.rs`).
/// `--wal FILE` — append every server event to a sha256-chained
/// write-ahead log ([`vgp::boinc::wal`]); a crashed run replays to its
/// exact pre-crash state.
/// `--pipeline` — route every DES server interaction through the
/// multi-daemon pipeline ([`vgp::boinc::daemon`]) as `vgp.rpc.v1`
/// requests instead of calling the core directly; trajectories are
/// bit-identical either way (`sim` + `tests/transport_equiv.rs`
/// differential proofs), so this is an exercise/verification knob.
fn sim_config_of(args: &Args) -> SimConfig {
    // --queue heap selects the reference BinaryHeap loop; trajectories
    // are bit-identical either way (sim::queue differential tests), so
    // this is purely a perf/debug knob
    let queue = args.opt_str("queue", "calendar");
    let queue = QueueKind::parse(queue).unwrap_or_else(|| {
        vgp::log_error!("unknown event queue '{queue}' (calendar|heap)");
        std::process::exit(2);
    });
    SimConfig {
        trace_capacity: args.opt_u64("trace", 0) as usize,
        wal: args.opt("wal").map(str::to_string),
        queue,
        pipeline: bool_flag(args, "pipeline"),
        ..SimConfig::default()
    }
}

/// `--metrics-out FILE`: persist a fleet snapshot (canonical JSON,
/// schema `vgp.fleet.v1`) for later `vgp dashboard --from FILE`.
fn write_metrics_out(args: &Args, snapshot: &Json) {
    let Some(path) = args.opt("metrics-out") else { return };
    if matches!(snapshot, Json::Null) {
        vgp::log_warn!("--metrics-out: this run produced no fleet snapshot");
        return;
    }
    match std::fs::write(path, format!("{snapshot}\n")) {
        Ok(()) => vgp::log_info!("fleet snapshot written to {path}"),
        Err(e) => vgp::log_error!("--metrics-out {path}: {e}"),
    }
}

fn cmd_sim(args: &Args) -> i32 {
    if let Some(t) = args.opt("table") {
        return sim_table(t);
    }
    // --config FILE: campaign from [campaign], pool from [pool]
    // (the INI route documented in the config module); a `demes` key
    // selects the island-model path
    if let Some(path) = args.opt("config") {
        let cfg = Config::load(path).expect("config file");
        let hosts = cfg.u64_or("pool", "hosts", 10) as usize;
        let pool = pool_from(
            cfg.str_or("pool", "churn", "lab"),
            hosts,
            cfg.u64_or("pool", "ncpus", 1) as u32,
            cfg.str_or("pool", "scenario", "steady"),
        );
        let seed = cfg.u64_or("pool", "seed", 7);
        if cfg.get("campaign", "demes").is_some() {
            let c = IslandCampaign::from_config(&cfg).expect("campaign section");
            let r = simulate_island_campaign(&c, &pool, &[("cfg", hosts)], sim_config_of(args), seed);
            print_island_report(&r);
            write_metrics_out(args, &r.snapshot);
            return 0;
        }
        let c = Campaign::from_config(&cfg).expect("campaign section");
        let r = simulate_campaign(&c, &pool, &[("cfg", hosts)], sim_config_of(args), seed);
        print_report(&r);
        write_metrics_out(args, &r.snapshot);
        return 0;
    }
    // --demes N: island-model campaign (WUs are executed for real so
    // the exchange can route checkpoints + emigrants between epochs)
    if args.opt("demes").is_some() {
        let problem = ProblemKind::parse(args.opt_str("problem", "mux6")).expect("problem");
        let hosts = args.opt_u64("hosts", 10) as usize;
        let c = island_campaign_from_args(args, "cli_islands", problem);
        let r = simulate_island_campaign(
            &c,
            &pool_of(args, hosts),
            &[("cli", hosts)],
            sim_config_of(args),
            args.opt_u64("seed", 7),
        );
        print_island_report(&r);
        write_metrics_out(args, &r.snapshot);
        return 0;
    }
    let problem = ProblemKind::parse(args.opt_str("problem", "mux11")).expect("problem");
    let runs = args.opt_u64("runs", 25) as usize;
    let gens = args.opt_u64("generations", 50) as usize;
    let pop = args.opt_u64("population", 1000) as usize;
    let hosts = args.opt_u64("hosts", 10) as usize;
    let seed = args.opt_u64("seed", 7);
    let mut c = Campaign::new("cli", problem, runs, gens, pop);
    c.threads = args.opt_u64("threads", 1).max(1) as usize;
    c.eval_lanes = eval_lanes_of(args);
    c.reg_lanes = reg_lanes_of(args);
    c.schedule = schedule_of(args);
    if c.threads > 1 {
        // the DES models durations from FLOPs/host-rate; worker thread
        // fan-out only applies when WUs are actually executed (serve/
        // worker). Scale virtual hosts with --ncpus instead.
        vgp::log_warn!(
            "--threads affects real WU execution (vgp serve/worker), not DES \
             durations; use --ncpus to give simulated hosts more cores"
        );
    }
    let r =
        simulate_campaign(&c, &pool_of(args, hosts), &[("cli", hosts)], sim_config_of(args), seed);
    print_report(&r);
    write_metrics_out(args, &r.snapshot);
    0
}

fn print_island_report(r: &IslandReport) {
    let o = &r.outcome;
    emit(&format!(
        "islands {}: T_B={:.0}s acc={:.2} done={}/{} | migrations: {} released, {} migrants, {} timeouts, {} cancelled",
        r.campaign,
        o.makespan,
        o.speedup,
        o.completed,
        o.total_wus,
        r.stats.released,
        r.stats.immigrants_delivered,
        r.stats.timeouts,
        r.stats.cancelled
    ));
    match &r.best {
        Some(b) => emit(&format!(
            "best: raw={} hits={} from deme {} epoch {} ({} nodes)",
            b.raw,
            b.hits,
            b.deme,
            b.epoch,
            b.tree.len()
        )),
        None => emit("best: none (campaign produced no validated payloads)"),
    }
}

fn print_report(r: &vgp::coordinator::CampaignReport) {
    emit(&format!(
        "campaign {}: T_seq={:.0}s T_B={:.0}s acc={:.2} CP={:.1} GFLOPS done={}/{} hosts={}/{}",
        r.campaign,
        r.t_seq,
        r.t_b,
        r.acceleration,
        r.cp_gflops,
        r.completed,
        r.runs,
        r.productive_hosts,
        r.attached_hosts
    ));
}

fn sim_table(which: &str) -> i32 {
    match which {
        "1" => {
            let mut table = Table::new(&["config", "clients", "T_seq", "T_B", "Acc"]);
            for (gens, pop) in [(1000usize, 1000usize), (1000, 2000), (2000, 1000)] {
                for clients in [5usize, 10] {
                    let c = Campaign::new(
                        &format!("ant_g{gens}_p{pop}"),
                        ProblemKind::Ant,
                        25,
                        gens,
                        pop,
                    );
                    let r = simulate_campaign(
                        &c,
                        &PoolParams::lab(clients),
                        &[("lab", clients)],
                        SimConfig::default(),
                        42,
                    );
                    table.row(&[
                        format!("{gens} Gen, {pop} Ind"),
                        clients.to_string(),
                        format!("{:.0}s", r.t_seq),
                        format!("{:.0}s", r.t_b),
                        format!("{:.2}", r.acceleration),
                    ]);
                }
            }
            table.print();
        }
        "2" => {
            let mut table = Table::new(&["campaign", "runs", "T_seq", "T_B", "Acc", "CP"]);
            let mux11 = Campaign::new("mux11", ProblemKind::Mux11, 828, 50, 4000);
            let r11 = simulate_campaign(
                &mux11,
                &PoolParams::volunteer(45),
                FIG1_CITIES_MUX11,
                SimConfig::default(),
                42,
            );
            let mux20 = Campaign::new("mux20", ProblemKind::Mux20, 42, 50, 1000);
            let r20 = simulate_campaign(
                &mux20,
                &PoolParams::volunteer(41),
                FIG1_CITIES_MUX20,
                SimConfig::default(),
                42,
            );
            for r in [r11, r20] {
                table.row(&[
                    r.campaign.clone(),
                    r.runs.to_string(),
                    format!("{:.0}s", r.t_seq),
                    format!("{:.0}s", r.t_b),
                    format!("{:.2}", r.acceleration),
                    format!("{:.1} GF", r.cp_gflops),
                ]);
            }
            table.print();
        }
        "3" => {
            let c = Campaign::new("ip", ProblemKind::InterestPoint, 12, 75, 75);
            let r = simulate_campaign(
                &c,
                &PoolParams::virtualized_lab(10),
                &[("windows-lab", 10)],
                SimConfig::default(),
                42,
            );
            let mut table = Table::new(&["config", "T_seq", "T_B", "Acc", "CP"]);
            table.row(&[
                "75 Gen, 75 Ind (virtualized)".into(),
                format!("{:.1}h", r.t_seq / 3600.0),
                format!("{:.1}h", r.t_b / 3600.0),
                format!("{:.2}", r.acceleration),
                format!("{:.1} GF", r.cp_gflops),
            ]);
            table.print();
        }
        other => {
            vgp::log_error!("unknown table '{other}' (1|2|3)");
            return 2;
        }
    }
    0
}

/// `--wal FILE` on `serve`: verify + load any existing event log for
/// crash replay, and open the writer that will extend its hash chain.
fn open_wal_or_die(path: &str) -> (Vec<vgp::boinc::events::Event>, vgp::boinc::wal::WalWriter) {
    vgp::boinc::wal::WalWriter::open_or_create(path).unwrap_or_else(|e| {
        vgp::log_error!("--wal {path}: {e:#}");
        std::process::exit(2);
    })
}

fn cmd_serve(args: &Args) -> i32 {
    let problem = ProblemKind::parse(args.opt_str("problem", "mux6")).expect("problem");
    let pop = args.opt_u64("population", 200) as usize;
    let threads = args.opt_u64("threads", 1).max(1) as usize;
    // --port N: bind 127.0.0.1:N (0 = kernel-assigned ephemeral port,
    // printed on the "vgp ... server on" line either way)
    let port = args.opt_u64("port", 0) as u16;
    // --demes N: serve an island campaign — the migration exchange
    // runs in this loop, behind the assimilator, releasing each epoch
    // as its dependencies reach quorum
    let trace_cap = args.opt_u64("trace", 0) as usize;
    if args.opt("demes").is_some() {
        let c = island_campaign_from_args(args, "served_islands", problem);
        let mut core = ServerCore::new(ServerConfig::default());
        if trace_cap > 0 {
            core.trace.enable(trace_cap);
        }
        let mut ex = MigrationExchange::new(c.exchange_config());
        match args.opt("wal") {
            Some(path) => {
                let (events, writer) = open_wal_or_die(path);
                if events.is_empty() {
                    core.attach_wal(writer);
                    ex.install(&mut core, c.workunits());
                } else {
                    // crash recovery: rebuild core + exchange from the
                    // log, then extend the same chain with new events
                    emit(&format!("replaying {} WAL events from {path}", events.len()));
                    vgp::boinc::wal::replay(&mut core, Some(&mut ex), events);
                    core.attach_wal(writer);
                }
            }
            None => ex.install(&mut core, c.workunits()),
        }
        // the exchange moves into the Service: the reactor's periodic
        // tick drives transitioner + daemons + exchange poll, so this
        // loop only observes
        let handle = serve_service(Service::new(core, Some(ex)), port).expect("serve");
        emit(&format!(
            "vgp island server on {} ({} demes x {} epochs of {}); Ctrl-C to stop",
            handle.addr,
            c.demes,
            c.epochs,
            problem.name()
        ));
        loop {
            std::thread::sleep(std::time::Duration::from_secs(2));
            let svc = handle.service.lock().unwrap();
            write_metrics_out(args, &svc.snapshot(handle.now()));
            let st = svc.core.db.stats();
            emit(&format!("wus {}/{} done; {} in progress", st.wus_done, st.wus, st.in_progress));
            if svc.core.is_complete() {
                match c.merge_best(svc.core.assimilated()) {
                    Some(b) => emit(&format!(
                        "campaign complete; best raw={} hits={} (deme {}, epoch {})",
                        b.raw, b.hits, b.deme, b.epoch
                    )),
                    None => emit("campaign complete; no validated payloads"),
                }
                return 0;
            }
        }
    }
    let runs = args.opt_u64("runs", 8) as usize;
    let gens = args.opt_u64("generations", 20) as usize;
    let mut c = Campaign::new("served", problem, runs, gens, pop);
    c.threads = threads;
    c.eval_lanes = eval_lanes_of(args);
    c.reg_lanes = reg_lanes_of(args);
    c.schedule = schedule_of(args);
    let mut core = ServerCore::new(ServerConfig::default());
    if trace_cap > 0 {
        core.trace.enable(trace_cap);
    }
    match args.opt("wal") {
        Some(path) => {
            let (events, writer) = open_wal_or_die(path);
            if events.is_empty() {
                core.attach_wal(writer);
                for wu in c.workunits() {
                    core.submit_wu(wu);
                }
            } else {
                emit(&format!("replaying {} WAL events from {path}", events.len()));
                vgp::boinc::wal::replay(&mut core, None, events);
                core.attach_wal(writer);
            }
        }
        None => {
            for wu in c.workunits() {
                core.submit_wu(wu);
            }
        }
    }
    let handle = serve_service(Service::new(core, None), port).expect("serve");
    emit(&format!("vgp server on {} ({runs} WUs of {}); Ctrl-C to stop", handle.addr, problem.name()));
    loop {
        std::thread::sleep(std::time::Duration::from_secs(2));
        let svc = handle.service.lock().unwrap();
        write_metrics_out(args, &svc.snapshot(handle.now()));
        let st = svc.core.db.stats();
        emit(&format!("wus {}/{} done; {} in progress", st.wus_done, st.wus, st.in_progress));
        if svc.core.is_complete() {
            emit("campaign complete");
            return 0;
        }
    }
}

fn cmd_worker(args: &Args) -> i32 {
    let addr: std::net::SocketAddr =
        args.opt_str("addr", "127.0.0.1:0").parse().expect("--addr host:port");
    let key = vgp::boinc::signature::SigningKey::new(b"vgp-project-key");
    let worker = Worker {
        name: args.opt_str("name", "worker").to_string(),
        city: args.opt_str("city", "local").to_string(),
        flops: args.opt_f64("flops", 1.3e9),
        poll_interval: std::time::Duration::from_millis(args.opt_u64("poll-ms", 500)),
    };
    // run_wu_auto_rt dispatches on the spec shape (whole-run vs island
    // epoch) AND the spec's `path` key (Method 1 native vs Method 2
    // artifact) — one worker binary serves every campaign kind. The
    // runtime loads opportunistically: without artifacts/ the worker
    // still serves native WUs, and artifact WUs fail cleanly so the
    // server reissues them to a capable host.
    let rt = vgp::runtime::Runtime::autoload();
    if rt.is_some() {
        vgp::log_info!("artifact runtime loaded: serving Method-2 (artifact-path) WUs");
    }
    let mut conn = Connection::connect(addr).unwrap_or_else(|e| {
        vgp::log_error!("worker: cannot reach {addr}: {e:#}");
        std::process::exit(2);
    });
    let report = worker
        .run(&mut conn, &key, &|spec| exec::run_wu_auto_rt(rt.as_ref(), spec))
        .expect("worker run");
    emit(&format!(
        "worker done: {} completed, {} errors, {:.1}s cpu",
        report.completed, report.errors, report.cpu_time
    ));
    0
}

fn cmd_churn(args: &Args) -> i32 {
    let days = args.opt_u64("days", 30) as usize;
    let hosts_n = args.opt_u64("hosts", 41) as usize;
    let params = pool_from("volunteer", hosts_n, 1, args.opt_str("scenario", "steady"));
    let mut rng = Rng::new(args.opt_u64("seed", 9));
    let hosts = sample_pool(&mut rng, &params, FIG1_CITIES_MUX20);
    let tr = churn_trace(&hosts, days);
    let title = format!(
        "active volunteer hosts per day (Fig 2, {} scenario)",
        params.scenario.name()
    );
    emit(&ascii_plot(&title, &tr.days, &tr.active_hosts, 12));
    let _ = FIG1_CITIES_MUX11;
    0
}

/// `vgp dashboard --from fleet.json [--bench BENCH.json]
/// [--require-nonzero a,b]`: schema-validate a snapshot written by
/// `--metrics-out` and render the ASCII fleet view (hosts, campaign
/// progress, exchange stats, counters, trace tail). `--require-nonzero`
/// takes a comma-separated counter-name list and exits 1 when any is
/// zero — the CI observability smoke gate.
fn cmd_dashboard(args: &Args) -> i32 {
    let Some(path) = args.opt("from") else {
        vgp::log_error!("usage: vgp dashboard --from fleet.json [--bench FILE] [--require-nonzero a,b]");
        return 2;
    };
    let snap = match validate_snapshot_json(path) {
        Ok(s) => s,
        Err(e) => {
            vgp::log_error!("invalid snapshot {path}: {e:#}");
            return 2;
        }
    };
    for line in dashboard::render(&snap).lines() {
        emit(line);
    }
    if let Some(bench) = args.opt("bench") {
        match dashboard::render_bench(bench) {
            Ok(panel) => {
                for line in panel.lines() {
                    emit(line);
                }
            }
            Err(e) => {
                vgp::log_error!("bench panel {bench}: {e:#}");
                return 2;
            }
        }
    }
    if let Some(list) = args.opt("require-nonzero") {
        let names: Vec<&str> = list.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
        if let Err(e) = dashboard::require_nonzero(&snap, &names) {
            vgp::log_error!("require-nonzero: {e:#}");
            return 1;
        }
        emit(&format!("require-nonzero ok: {}", names.join(", ")));
    }
    0
}

/// `vgp lint [--src DIR]`: run the repo determinism lint over the
/// crate sources. Exit 0 when clean, 1 on findings (the CI gate).
fn cmd_lint(args: &Args) -> i32 {
    let default_src = concat!(env!("CARGO_MANIFEST_DIR"), "/src");
    let src = std::path::PathBuf::from(args.opt_str("src", default_src));
    let findings = match vgp::lint::lint_crate(&src) {
        Ok(f) => f,
        Err(e) => {
            vgp::log_error!("lint failed to scan {}: {e:#}", src.display());
            return 2;
        }
    };
    for f in &findings {
        emit(&f.to_string());
    }
    let nfiles = vgp::lint::count_rs(&src).unwrap_or(0);
    if findings.is_empty() {
        emit(&format!(
            "lint clean: {nfiles} files, {} rules + forbid-unsafe, 0 findings",
            vgp::lint::RULES.len()
        ));
        0
    } else {
        vgp::log_error!("lint: {} finding(s) in {nfiles} files", findings.len());
        1
    }
}
