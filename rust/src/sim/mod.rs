//! Deterministic discrete-event simulation of a volunteer-computing
//! campaign: the *same* [`ServerCore`] state machines as the TCP
//! deployment, driven in virtual time by simulated hosts with churn.
//!
//! This regenerates the paper's Tables 1–3 in seconds of wall clock:
//! speedup and computing power are functions of event ordering and
//! durations, both of which the DES preserves (DESIGN.md §2).
//!
//! Event loop: host arrival → poll (scheduler RPC) → compute (duration
//! = WU FLOPs / host effective FLOPS, with client-error injection) →
//! report; host departure kills in-flight work (the server's deadline
//! pass reissues it). Ties are broken by sequence number, so a given
//! seed reproduces the identical trajectory.
//!
//! **Million-host engine:** events are scheduled through a calendar
//! queue ([`queue::EventQueue`], amortized O(1) push/pop; the
//! reference `BinaryHeap` stays selectable for differential proofs),
//! host state lives in a structure-of-arrays [`HostSlab`] (interned
//! cities, lazily formatted names — no per-host `String` churn on the
//! register path), and the loop does no O(fleet) work per event: the
//! attached-host count is maintained incrementally and termination is
//! a pending-work counter, not a queue scan. Server-side, `tick`
//! expiry and per-host in-progress queries ride the deadline wheel in
//! [`crate::boinc::db`].
//!
//! **Per-core task model:** a host queues up to `ncpus` concurrent WUs
//! (BOINC schedules one task per CPU), each computing at the host's
//! per-core effective rate — so island epochs genuinely overlap on
//! multi-core volunteers instead of being folded into one rate
//! multiplier.
//!
//! **Executors and the exchange:** by default a completion fabricates a
//! hash-stable placeholder payload (enough for the paper's run-level
//! campaigns). An attached [`WuExecutor`] instead *runs the WU spec for
//! real* — island campaigns need true checkpoints/emigrants for the
//! attached [`MigrationExchange`] to route between epochs.
//!
//! **Pipeline mode** (`SimConfig::pipeline`): instead of calling the
//! `ServerCore` convenience wrappers, every simulated RPC goes through
//! [`crate::boinc::daemon::handle_request`] — the same multi-daemon
//! scheduler/feeder path the TCP reactor serves. The daemons emit the
//! identical `Event` sequence (their caches are pure read-side state),
//! so direct and pipeline runs produce byte-identical fleet snapshots
//! — the DES is a second driver of the production code path, proven by
//! `tests/transport_equiv.rs`.

pub mod queue;

use crate::boinc::daemon::{self, DaemonConfig, Daemons};
use crate::boinc::db::HostRow;
use crate::boinc::exchange::MigrationExchange;
use crate::boinc::protocol::{Reply, Request};
use crate::boinc::server::{ServerConfig, ServerCore};
use crate::boinc::workunit::WorkUnit;
use crate::churn::{ComputingPower, HostSlab, SimHost};
use crate::metrics::{Counter, Gauge};
use crate::util::json::Json;
use crate::util::rng::Rng;

use queue::{EventQueue, QueueKind};

/// Executes a WU spec at (virtual) completion time, producing the
/// result payload a real client would upload. Must be deterministic in
/// the spec for quorum agreement to work.
pub type WuExecutor = Box<dyn FnMut(&Json) -> anyhow::Result<Json>>;

/// Simulator tuning.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// scheduler-RPC polling interval, seconds (BOINC work-fetch backoff)
    pub poll_interval: f64,
    /// per-WU download+upload overhead, seconds (2007 DSL + server I/O)
    pub transfer_overhead: f64,
    /// server transitioner cadence, seconds
    pub tick_interval: f64,
    /// hard stop (safety), virtual seconds
    pub max_virtual_time: f64,
    /// WU-lifecycle trace ring capacity (`crate::metrics::trace`);
    /// 0 keeps tracing off. Tracing is payload-neutral — enabling it
    /// cannot change a canonical payload byte (tests prove it).
    pub trace_capacity: usize,
    /// When set, every server event is appended to this write-ahead
    /// log (`crate::boinc::wal`) before it is applied, so a crashed
    /// run can be replayed to its exact pre-crash state.
    pub wal: Option<String>,
    /// Event-queue implementation. Calendar and Heap pop in the
    /// identical total order, so this knob cannot change a trajectory
    /// — only how fast it runs (proven by the differential tests).
    pub queue: QueueKind,
    /// Route every simulated RPC through the multi-daemon pipeline
    /// ([`crate::boinc::daemon`]) instead of the `ServerCore`
    /// convenience wrappers. Event-sequence-neutral: the daemons are
    /// read-side state over the same events, so this knob cannot
    /// change a trajectory either (proven by `tests/transport_equiv`).
    pub pipeline: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            poll_interval: 60.0,
            transfer_overhead: 30.0,
            tick_interval: 600.0,
            max_virtual_time: 120.0 * 86400.0,
            trace_capacity: 0,
            wal: None,
            queue: QueueKind::Calendar,
            pipeline: false,
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrive(usize),
    Depart(usize),
    Poll(usize),
    Complete { host: usize, rid: u64, ok: bool, cpu: f64 },
    Tick,
}

/// Result of one simulated campaign.
#[derive(Clone, Debug)]
pub struct SimOutcome {
    /// paper T_B: first client registration .. last server communication
    pub makespan: f64,
    /// wall-clock the same WUs need sequentially on one reference host
    pub t_seq: f64,
    /// eq. 1 acceleration
    pub speedup: f64,
    /// assimilated WU count
    pub completed: usize,
    pub total_wus: usize,
    /// hosts that returned >= 1 valid result (paper: "only 27 of 45")
    pub productive_hosts: usize,
    pub attached_hosts: usize,
    /// eq. 2 computing power over the campaign window
    pub cp_gflops: f64,
    /// per-WU completion times (virtual secs since start)
    pub completions: Vec<f64>,
    pub client_errors: u64,
    pub no_replies: u64,
    /// attached-executor failures (e.g. an artifact-path campaign run
    /// where the runtime cannot serve the spec) — infrastructure
    /// problems, counted separately from simulated client churn
    pub executor_failures: u64,
    /// DES events popped (the `benches/des.rs` throughput numerator)
    pub events_processed: u64,
}

/// A prepared simulation: server + WUs + host pool.
pub struct Simulation {
    pub core: ServerCore,
    pub cfg: SimConfig,
    slab: HostSlab,
    host_ids: Vec<u64>,
    attached: Vec<bool>,
    /// attached-host count, maintained incrementally (never recounted)
    attached_count: usize,
    /// WUs currently computing on each host (per-core task model:
    /// bounded by the host's ncpus)
    active: Vec<u32>,
    rng: Rng,
    exchange: Option<MigrationExchange>,
    executor: Option<WuExecutor>,
    /// present iff `cfg.pipeline`: the daemon set the virtual-time RPCs
    /// run through (feeder cache, typed queues, host lanes)
    daemons: Option<Daemons>,
}

impl Simulation {
    pub fn new(cfg: SimConfig, server_cfg: ServerConfig, hosts: Vec<SimHost>, seed: u64) -> Self {
        Simulation::from_slab(cfg, server_cfg, HostSlab::from_hosts(&hosts), seed)
    }

    /// Build directly from slab columns — the million-host entry
    /// point, skipping any per-host struct materialization.
    pub fn from_slab(cfg: SimConfig, server_cfg: ServerConfig, slab: HostSlab, seed: u64) -> Self {
        let mut core = ServerCore::new(server_cfg);
        if cfg.trace_capacity > 0 {
            core.trace.enable(cfg.trace_capacity);
        }
        if let Some(path) = &cfg.wal {
            match crate::boinc::wal::WalWriter::create(path) {
                Ok(w) => core.attach_wal(w),
                Err(e) => crate::log_error!("sim: wal {path}: {e:#}"),
            }
        }
        let daemons = cfg.pipeline.then(|| Daemons::new(DaemonConfig::default()));
        Simulation {
            core,
            host_ids: vec![0; slab.len()],
            attached: vec![false; slab.len()],
            attached_count: 0,
            active: vec![0; slab.len()],
            slab,
            cfg,
            rng: Rng::new(seed ^ 0x51315),
            exchange: None,
            executor: None,
            daemons,
        }
    }

    /// Pipeline-mode telemetry (cache hits, queue drains), if enabled.
    pub fn daemons(&self) -> Option<&Daemons> {
        self.daemons.as_ref()
    }

    /// The simulated pool, in slab form.
    pub fn hosts(&self) -> &HostSlab {
        &self.slab
    }

    pub fn submit(&mut self, wu: WorkUnit) -> u64 {
        self.core.submit_wu(wu)
    }

    /// Attach a migration exchange (install its WUs into `self.core`
    /// first); it is polled after every report and transitioner tick.
    pub fn attach_exchange(&mut self, ex: MigrationExchange) {
        self.exchange = Some(ex);
    }

    pub fn exchange(&self) -> Option<&MigrationExchange> {
        self.exchange.as_ref()
    }

    /// Execute WU specs for real at completion time instead of
    /// fabricating placeholder payloads (required for island
    /// campaigns — the exchange routes actual checkpoint/emigrant
    /// content).
    pub fn set_executor(&mut self, f: WuExecutor) {
        self.executor = Some(f);
    }

    /// Reference sequential time: all WUs on one dedicated mean host
    /// (the paper's `T_seq` baseline machine).
    pub fn sequential_time(&self, reference_flops: f64) -> f64 {
        self.core
            .db
            .wus
            .values()
            .map(|wu| wu.flops_est / reference_flops)
            .sum()
    }

    /// Run to campaign completion (or the safety horizon).
    pub fn run(mut self, reference_flops: f64) -> SimOutcome {
        self.run_mut(reference_flops)
    }

    /// Like [`Simulation::run`], but leaves the simulation inspectable
    /// afterwards (assimilated payloads, exchange stats, host table).
    pub fn run_mut(&mut self, reference_flops: f64) -> SimOutcome {
        let t_seq = self.sequential_time(reference_flops);
        let total_wus = self.core.db.wus.len();
        let mut q: EventQueue<Ev> = EventQueue::new(self.cfg.queue);
        // queued events that are not departures; `is_complete() &&
        // pending_work == 0` is the O(1) termination test that replaces
        // scanning the whole queue for a non-Depart entry
        let mut pending_work: u64 = 0;
        let push = |q: &mut EventQueue<Ev>, pw: &mut u64, at: f64, ev: Ev| {
            if !matches!(ev, Ev::Depart(_)) {
                *pw += 1;
            }
            q.push(at, ev);
        };

        for i in 0..self.slab.len() {
            push(&mut q, &mut pending_work, self.slab.arrival[i], Ev::Arrive(i));
        }
        push(&mut q, &mut pending_work, self.cfg.tick_interval, Ev::Tick);

        #[allow(unused_assignments)]
        let mut now = 0.0;
        let mut last_comm: f64 = 0.0;
        let mut first_reg = f64::INFINITY;
        let mut events_processed: u64 = 0;

        while let Some((at, ev)) = q.pop() {
            now = at;
            events_processed += 1;
            if !matches!(ev, Ev::Depart(_)) {
                pending_work -= 1;
            }
            if now > self.cfg.max_virtual_time {
                break;
            }
            match ev {
                Ev::Arrive(i) => {
                    let id = if let Some(daemons) = self.daemons.as_mut() {
                        let req = Request::Register {
                            name: self.slab.name_of(i),
                            city: self.slab.city_of(i).to_string(),
                            flops: self.slab.flops[i],
                            ncpus: self.slab.ncpus[i],
                            on_frac: self.slab.on_frac[i],
                            active_frac: self.slab.active_frac[i],
                        };
                        let reply = daemon::handle_request(
                            &mut self.core,
                            daemons,
                            self.exchange.as_mut(),
                            &req,
                            now,
                        );
                        match reply {
                            Reply::Registered { host_id } => host_id,
                            other => panic!("sim register failed: {other:?}"),
                        }
                    } else {
                        self.core.register_host(HostRow {
                            id: 0,
                            name: self.slab.name_of(i),
                            city: self.slab.city_of(i).to_string(),
                            flops: self.slab.flops[i],
                            ncpus: self.slab.ncpus[i],
                            on_frac: self.slab.on_frac[i],
                            active_frac: self.slab.active_frac[i],
                            registered_at: now,
                            last_heartbeat: now,
                            error_results: 0,
                            valid_results: 0,
                            consecutive_errors: 0,
                            last_error_at: 0.0,
                            in_flight: 0,
                            credit: 0.0,
                        })
                    };
                    self.host_ids[i] = id;
                    self.attached[i] = true;
                    self.attached_count += 1;
                    first_reg = first_reg.min(now);
                    last_comm = last_comm.max(now);
                    push(&mut q, &mut pending_work, now + 1.0, Ev::Poll(i));
                    push(&mut q, &mut pending_work, self.slab.departure[i], Ev::Depart(i));
                }
                Ev::Depart(i) => {
                    if self.attached[i] {
                        self.attached[i] = false;
                        self.attached_count -= 1;
                    }
                    self.core
                        .metrics
                        .set_gauge(Gauge::HostsAttached, self.attached_count as f64);
                    // in-flight work is silently lost; the server's
                    // deadline pass turns it into NO_REPLY later
                }
                Ev::Poll(i) => {
                    if !self.attached[i] || self.active[i] >= self.slab.ncpus[i].max(1) {
                        continue; // saturated: the next Complete re-polls
                    }
                    if self.core.is_complete() {
                        continue;
                    }
                    last_comm = last_comm.max(now);
                    // pipeline mode serves from the feeder cache; direct
                    // mode from the ServerCore wrapper — same event either
                    // way, and the sim only needs (result id, flops_est)
                    let got = if let Some(daemons) = self.daemons.as_mut() {
                        let req = Request::RequestWork { host_id: self.host_ids[i] };
                        match daemon::handle_request(
                            &mut self.core,
                            daemons,
                            self.exchange.as_mut(),
                            &req,
                            now,
                        ) {
                            Reply::Work { result_id, flops_est, .. } => {
                                Some((result_id, flops_est))
                            }
                            _ => None,
                        }
                    } else {
                        self.core
                            .request_work(self.host_ids[i], now)
                            .map(|(rid, wu, _sig)| (rid, wu.flops_est))
                    };
                    match got {
                        Some((rid, flops_est)) => {
                            self.active[i] += 1;
                            // per-core task model: each concurrent WU
                            // computes on ONE core at the host's
                            // effective per-core rate; ncpus shows up as
                            // queue width, not as a rate multiplier
                            let compute = flops_est / self.slab.effective_flops(i).max(1e3);
                            let dur = compute + self.cfg.transfer_overhead;
                            let ok = !self.rng.chance(self.slab.client_error_rate[i]);
                            // client errors surface early (crash on start)
                            let at = if ok { now + dur } else { now + dur.min(60.0) };
                            push(
                                &mut q,
                                &mut pending_work,
                                at,
                                Ev::Complete { host: i, rid, ok, cpu: compute },
                            );
                            // multi-core hosts keep fetching until their
                            // cores are full
                            push(&mut q, &mut pending_work, now + 1.0, Ev::Poll(i));
                        }
                        None => {
                            push(
                                &mut q,
                                &mut pending_work,
                                now + self.cfg.poll_interval,
                                Ev::Poll(i),
                            );
                        }
                    }
                }
                Ev::Complete { host: i, rid, ok, cpu } => {
                    self.active[i] = self.active[i].saturating_sub(1);
                    if !self.attached[i] {
                        continue; // host died mid-computation
                    }
                    last_comm = last_comm.max(now);
                    let payload = if !ok {
                        None
                    } else {
                        match self.executor.as_mut() {
                            // real execution: the payload is the WU's
                            // actual result content (island epochs)
                            Some(exec_fn) => {
                                let spec = self
                                    .core
                                    .db
                                    .result(rid)
                                    .and_then(|r| self.core.db.wu(r.wu_id))
                                    .map(|w| w.spec.clone());
                                match spec.map(|s| exec_fn(&s)) {
                                    Some(Ok(p)) => Some(p),
                                    Some(Err(e)) => {
                                        // surface the cause — an executor
                                        // failure is an infrastructure bug
                                        // (bad spec / missing artifacts),
                                        // not simulated churn
                                        crate::log_warn!("sim: WU execution failed: {e:#}");
                                        self.core.metrics.inc(Counter::SimExecutorFailure);
                                        None
                                    }
                                    None => None,
                                }
                            }
                            // placeholder: canonical run descriptor
                            // (hash-stable per WU so quorum agreement
                            // works)
                            None => {
                                let wu_id =
                                    self.core.db.result(rid).map(|r| r.wu_id).unwrap_or(0);
                                Some(Json::obj().set("wu", wu_id).set("status", "done"))
                            }
                        }
                    };
                    // report-then-exchange-poll, in both modes:
                    // handle_request polls internally after each report,
                    // keeping the event sequence identical to direct mode
                    if let Some(daemons) = self.daemons.as_mut() {
                        let req = match payload {
                            Some(p) => {
                                Request::ReportSuccess { result_id: rid, cpu_time: cpu, payload: p }
                            }
                            None => Request::ReportError { result_id: rid },
                        };
                        daemon::handle_request(
                            &mut self.core,
                            daemons,
                            self.exchange.as_mut(),
                            &req,
                            now,
                        );
                    } else {
                        match payload {
                            Some(p) => self.core.report_success(rid, now, cpu, p),
                            None => self.core.report_error(rid, now),
                        }
                        if let Some(ex) = self.exchange.as_mut() {
                            ex.poll(&mut self.core, now);
                        }
                    }
                    push(&mut q, &mut pending_work, now + 1.0, Ev::Poll(i));
                }
                Ev::Tick => {
                    // transitioner pass (+ daemon upkeep in pipeline
                    // mode), then the exchange — the same Tick-then-Poll
                    // order as the TCP reactor's Service::tick
                    match self.daemons.as_mut() {
                        Some(daemons) => daemons.tick(&mut self.core, now),
                        None => self.core.tick(now),
                    }
                    if let Some(ex) = self.exchange.as_mut() {
                        ex.poll(&mut self.core, now);
                    }
                    if !self.core.is_complete() {
                        push(&mut q, &mut pending_work, now + self.cfg.tick_interval, Ev::Tick);
                    }
                }
            }
            if self.core.is_complete() && pending_work == 0 {
                break;
            }
        }

        let makespan = (last_comm - first_reg.min(last_comm)).max(1e-9);
        let completions: Vec<f64> =
            self.core.assimilated().iter().map(|a| a.completed_at).collect();
        let productive: std::collections::HashSet<u64> =
            self.core.assimilated().iter().map(|a| a.host_id).collect();
        let window_days = makespan / 86400.0;
        let cp = ComputingPower::from_slab(&self.slab, window_days.max(0.1), 1.0, 1.0);
        SimOutcome {
            makespan,
            t_seq,
            speedup: t_seq / makespan,
            completed: completions.len(),
            total_wus,
            productive_hosts: productive.len(),
            attached_hosts: self.slab.len(),
            cp_gflops: cp.gflops(),
            completions,
            client_errors: self.core.metrics.get(Counter::ResultClientError),
            no_replies: self.core.metrics.get(Counter::ResultNoReply),
            executor_failures: self.core.metrics.get(Counter::SimExecutorFailure),
            events_processed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::churn::{sample_pool, PoolParams, Scenario, FIG1_CITIES_MUX11};
    use crate::metrics::snapshot::FleetSnapshot;
    use crate::util::json::Json;

    fn wus(n: usize, flops: f64) -> Vec<WorkUnit> {
        (0..n)
            .map(|i| WorkUnit::new(0, format!("wu_{i}"), Json::obj().set("i", i as u64), flops))
            .collect()
    }

    fn lab_sim(n_hosts: usize, n_wus: usize, flops_per_wu: f64) -> SimOutcome {
        let mut rng = Rng::new(7);
        let hosts = sample_pool(&mut rng, &PoolParams::lab(n_hosts), &[("lab", n_hosts)]);
        let mut sim =
            Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 7);
        for wu in wus(n_wus, flops_per_wu) {
            sim.submit(wu);
        }
        sim.run(1.3e9 * 0.95)
    }

    #[test]
    fn all_wus_complete_on_lab_pool() {
        let out = lab_sim(5, 25, 1e11);
        assert_eq!(out.completed, 25);
        assert_eq!(out.client_errors, 0);
        assert!(out.speedup > 1.0, "5 dedicated hosts must beat 1: {}", out.speedup);
        assert!(out.events_processed > 25, "every WU takes several events");
    }

    #[test]
    fn more_hosts_more_speedup() {
        let s5 = lab_sim(5, 25, 1e12).speedup;
        let s10 = lab_sim(10, 25, 1e12).speedup;
        assert!(s10 > s5, "paper Table 1: 10 clients beat 5 ({s5} vs {s10})");
        assert!(s5 > 2.0 && s5 <= 5.0);
        assert!(s10 > 4.0 && s10 <= 10.0);
    }

    #[test]
    fn short_tasks_poor_speedup_under_churn() {
        // the paper's 11-mux effect: ~135 s tasks + volunteer churn
        // gives speedup < 1 (T_B includes idle tails and overhead)
        let mut rng = Rng::new(11);
        let hosts = sample_pool(&mut rng, &PoolParams::volunteer(45), FIG1_CITIES_MUX11);
        let mut sim = Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 11);
        for wu in wus(120, 1.66e11) {
            // ~135s on a 1.3 GFLOPS host
            sim.submit(wu);
        }
        let out = sim.run(1.3e9 * 0.9);
        assert!(out.completed >= 100, "most short WUs done: {}", out.completed);
        assert!(out.speedup < 2.0, "churn should spoil short-task speedup: {}", out.speedup);
    }

    #[test]
    fn multicore_hosts_drain_campaign_faster() {
        let run = |ncpus: u32| {
            let mut rng = Rng::new(21);
            let hosts =
                sample_pool(&mut rng, &PoolParams::lab(4).with_ncpus(ncpus), &[("lab", 4)]);
            let mut sim =
                Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 21);
            for wu in wus(24, 1e12) {
                sim.submit(wu);
            }
            sim.run(1.3e9 * 0.95)
        };
        let single = run(1);
        let quad = run(4);
        assert_eq!(single.completed, 24);
        assert_eq!(quad.completed, 24);
        assert!(
            quad.makespan < single.makespan / 2.0,
            "4-core hosts must drain much faster: {} vs {}",
            quad.makespan,
            single.makespan
        );
        assert!(quad.cp_gflops > single.cp_gflops * 2.0, "eq. 2 must see the cores");
    }

    #[test]
    fn percore_model_queues_ncpus_wus_per_host() {
        // one dual-core host must OVERLAP two WUs (per-core task
        // queue), not merely drain one WU twice as fast
        let run = |ncpus: u32| {
            let mut rng = Rng::new(31);
            let hosts =
                sample_pool(&mut rng, &PoolParams::lab(1).with_ncpus(ncpus), &[("lab", 1)]);
            let mut sim =
                Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 31);
            for wu in wus(2, 1e12) {
                sim.submit(wu);
            }
            sim.run(1.3e9 * 0.95)
        };
        let single = run(1);
        let dual = run(2);
        assert_eq!(single.completed, 2);
        assert_eq!(dual.completed, 2);
        assert!(
            dual.makespan < single.makespan * 0.6,
            "2 cores must overlap 2 WUs: {} vs {}",
            dual.makespan,
            single.makespan
        );
    }

    #[test]
    fn executor_payloads_replace_placeholders() {
        let mut rng = Rng::new(5);
        let hosts = sample_pool(&mut rng, &PoolParams::lab(2), &[("lab", 2)]);
        let mut sim = Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 5);
        for wu in wus(3, 1e11) {
            sim.submit(wu);
        }
        sim.set_executor(Box::new(|spec| Ok(Json::obj().set("echo", spec.u64_of("i")?))));
        let out = sim.run_mut(1.3e9);
        assert_eq!(out.completed, 3);
        for a in sim.core.assimilated() {
            assert!(a.payload.get("echo").is_some(), "executor payload must be assimilated");
        }
    }

    #[test]
    fn executor_failures_are_counted_not_hidden() {
        let mut rng = Rng::new(5);
        let hosts = sample_pool(&mut rng, &PoolParams::lab(1), &[("lab", 1)]);
        let mut sim = Simulation::new(SimConfig::default(), ServerConfig::default(), hosts, 5);
        let mut wu = WorkUnit::new(0, "w", Json::obj(), 1e9);
        wu.max_error_results = 0; // first executor failure poisons the WU
        sim.submit(wu);
        sim.set_executor(Box::new(|_spec| anyhow::bail!("no runtime on this volunteer")));
        let out = sim.run_mut(1.3e9);
        assert_eq!(out.completed, 0);
        assert!(out.executor_failures >= 1, "failure must be visible in the outcome");
    }

    #[test]
    fn determinism_same_seed_same_outcome() {
        let a = lab_sim(5, 10, 1e11);
        let b = lab_sim(5, 10, 1e11);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completions, b.completions);
        assert_eq!(a.events_processed, b.events_processed);
    }

    #[test]
    fn host_death_recovers_via_reissue() {
        let mut rng = Rng::new(13);
        let mut hosts = sample_pool(&mut rng, &PoolParams::lab(3), &[("lab", 3)]);
        // one host dies 10 minutes in
        hosts[0].departure = 600.0;
        let mut sim = Simulation::new(
            SimConfig { tick_interval: 300.0, ..SimConfig::default() },
            ServerConfig { deadline_slack: 2.0, ..ServerConfig::default() },
            hosts,
            13,
        );
        for mut wu in wus(6, 1e12) {
            wu.delay_bound = 1800.0; // tight deadline so reissue happens
            sim.submit(wu);
        }
        let out = sim.run(1.3e9 * 0.95);
        assert_eq!(out.completed, 6, "reissue must recover lost work");
        assert!(out.no_replies >= 1, "the dead host's WU must expire");
    }

    /// The tentpole differential proof: for every scenario in the
    /// library, the calendar-queue loop reproduces the heap loop's
    /// fleet snapshot **byte-identically** (canonical `vgp.fleet.v1`
    /// JSON: host rows, WU counters, metrics registry — everything),
    /// along with the full outcome trajectory.
    #[test]
    fn calendar_queue_is_bit_identical_to_heap_on_every_scenario() {
        for &scenario in Scenario::ALL {
            let run = |kind: QueueKind| {
                let mut rng = Rng::new(42);
                let params = PoolParams::volunteer(60).with_scenario(scenario);
                let slab = crate::churn::HostSlab::sample(&mut rng, &params, FIG1_CITIES_MUX11);
                let mut sim = Simulation::from_slab(
                    SimConfig { queue: kind, ..SimConfig::default() },
                    ServerConfig::default(),
                    slab,
                    42,
                );
                for wu in wus(40, 1e10) {
                    sim.submit(wu);
                }
                let out = sim.run_mut(1.3e9 * 0.9);
                let snap =
                    FleetSnapshot::from_parts(&sim.core, None, out.makespan).to_json().to_string();
                (snap, out)
            };
            let (snap_h, out_h) = run(QueueKind::Heap);
            let (snap_c, out_c) = run(QueueKind::Calendar);
            assert_eq!(
                snap_h,
                snap_c,
                "fleet snapshot diverged under scenario {:?}",
                scenario
            );
            assert_eq!(out_h.completions, out_c.completions, "{scenario:?}");
            assert_eq!(out_h.makespan, out_c.makespan, "{scenario:?}");
            assert_eq!(out_h.events_processed, out_c.events_processed, "{scenario:?}");
            assert_eq!(out_h.no_replies, out_c.no_replies, "{scenario:?}");
        }
    }

    /// Pipeline mode routes every RPC through the multi-daemon path;
    /// since the daemons are pure read-side state over the same events,
    /// the fleet snapshot must not move by a byte.
    #[test]
    fn daemon_pipeline_is_bit_identical_to_direct_dispatch() {
        let run = |pipeline: bool| {
            let mut rng = Rng::new(42);
            let hosts = sample_pool(&mut rng, &PoolParams::volunteer(40), FIG1_CITIES_MUX11);
            let mut sim = Simulation::new(
                SimConfig { pipeline, ..SimConfig::default() },
                ServerConfig::default(),
                hosts,
                42,
            );
            for wu in wus(30, 1e10) {
                sim.submit(wu);
            }
            let out = sim.run_mut(1.3e9 * 0.9);
            let snap =
                FleetSnapshot::from_parts(&sim.core, None, out.makespan).to_json().to_string();
            let hits = sim.daemons().map(|d| d.stats.cache_hits).unwrap_or(0);
            (snap, out, hits)
        };
        let (snap_d, out_d, _) = run(false);
        let (snap_p, out_p, hits) = run(true);
        assert_eq!(snap_d, snap_p, "daemon pipeline changed the fleet snapshot");
        assert_eq!(out_d.completions, out_p.completions);
        assert_eq!(out_d.makespan, out_p.makespan);
        assert_eq!(out_d.events_processed, out_p.events_processed);
        assert!(out_p.completed > 0, "campaign must make progress");
        assert!(hits > 0, "the scheduler must actually serve from the feeder cache");
    }
}
