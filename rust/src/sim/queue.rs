//! Event queues for the DES: the reference binary heap and a calendar
//! (bucket) queue with amortized O(1) push/pop.
//!
//! Both implementations pop in the identical total order — ascending
//! `(virtual time, insertion seq)` — so swapping one for the other
//! cannot change a simulated trajectory by even a bit. The seq
//! tie-break is assigned internally by [`EventQueue::push`], preserving
//! the FIFO-at-equal-times semantics the simulator has always had.
//!
//! The calendar queue (R. Brown, CACM 1988) hashes events into a
//! power-of-two ring of time buckets of uniform `width`; a cursor walks
//! the ring one bucket-day at a time, so a pop touches only the
//! current day's bucket instead of rebalancing a log-depth heap. The
//! bucket count tracks the live event count (doubling/halving
//! rebuilds), keeping buckets O(1) occupied for roughly uniform event
//! spacing — the regime a million-host poll loop produces.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Order-preserving map from a non-NaN `f64` to `u64`:
/// `a < b ⇔ time_key(a) < time_key(b)`. Gives virtual times a total
/// order usable as a BTree/sort key without float comparators.
pub fn time_key(t: f64) -> u64 {
    let b = t.to_bits();
    if b >> 63 == 1 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Which event-queue implementation drives the DES loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// calendar/bucket queue: amortized O(1), the default
    Calendar,
    /// reference `BinaryHeap`: O(log n), kept for differential proofs
    Heap,
}

impl QueueKind {
    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "calendar" => Some(QueueKind::Calendar),
            "heap" => Some(QueueKind::Heap),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Calendar => "calendar",
            QueueKind::Heap => "heap",
        }
    }
}

struct Entry<T> {
    at: f64,
    seq: u64,
    item: T,
}

impl<T> Entry<T> {
    fn key(&self) -> (u64, u64) {
        (time_key(self.at), self.seq)
    }
}

// min-heap ordering on (at, seq) for the reference implementation
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        other.key().cmp(&self.key())
    }
}

const MIN_BUCKETS: usize = 16;

/// Brown's calendar queue. Buckets hold entries sorted *descending* by
/// `(at, seq)` so the minimum of a bucket pops from the back in O(1).
pub struct CalendarQueue<T> {
    buckets: Vec<Vec<Entry<T>>>,
    /// bucket count - 1 (count is a power of two)
    mask: usize,
    /// seconds of virtual time per bucket
    width: f64,
    len: usize,
    /// the cursor: virtual day index `floor(at / width)` being drained
    cur_day: u64,
}

impl<T> CalendarQueue<T> {
    pub fn new() -> Self {
        CalendarQueue {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: MIN_BUCKETS - 1,
            width: 1.0,
            len: 0,
            cur_day: 0,
        }
    }

    fn day_of(&self, at: f64) -> u64 {
        (at / self.width) as u64
    }

    fn push(&mut self, e: Entry<T>) {
        debug_assert!(!e.at.is_nan(), "NaN virtual time");
        let idx = (self.day_of(e.at) as usize) & self.mask;
        let b = &mut self.buckets[idx];
        // descending (at, seq): find the insertion point from a back
        // binary search — new events usually sort last (latest time)
        let key = e.key();
        let pos = b.partition_point(|x| x.key() > key);
        b.insert(pos, e);
        self.len += 1;
        if self.len > 2 * (self.mask + 1) {
            self.resize(2 * (self.mask + 1));
        }
    }

    fn pop(&mut self) -> Option<Entry<T>> {
        if self.len == 0 {
            return None;
        }
        // walk the ring one bucket-day at a time from the cursor; an
        // entry counts as "today" only when it falls inside the day's
        // window (same bucket a year later must wait a full lap)
        let mut day = self.cur_day;
        for _ in 0..=self.mask {
            let idx = (day as usize) & self.mask;
            let top = (day + 1) as f64 * self.width;
            if let Some(e) = self.buckets[idx].last() {
                if e.at < top {
                    self.cur_day = day;
                    return self.take(idx);
                }
            }
            day += 1;
        }
        // sparse tail (or an event behind the cursor): direct search
        // for the global minimum across bucket backs
        let idx = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| b.last().map(|e| (e.key(), i)))
            .min()
            .map(|(_, i)| i)
            .expect("len > 0");
        self.cur_day = self.day_of(self.buckets[idx].last().expect("nonempty").at);
        self.take(idx)
    }

    fn take(&mut self, idx: usize) -> Option<Entry<T>> {
        let e = self.buckets[idx].pop();
        self.len -= 1;
        if self.len < (self.mask + 1) / 4 && self.mask + 1 > MIN_BUCKETS {
            self.resize((self.mask + 1) / 2);
        }
        e
    }

    /// Rebuild with `nbuckets` buckets and a width matched to the mean
    /// event spacing, so one bucket-day holds O(1) events.
    fn resize(&mut self, nbuckets: usize) {
        let nbuckets = nbuckets.next_power_of_two().max(MIN_BUCKETS);
        let mut entries: Vec<Entry<T>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            entries.append(b);
        }
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for e in &entries {
            lo = lo.min(e.at);
            hi = hi.max(e.at);
        }
        // a few events per bucket-day; degenerate spans (all events at
        // one instant) keep a positive width and lean on direct search
        let mut width = (hi - lo) / (entries.len().max(1) as f64) * 4.0;
        if !width.is_finite() || width <= 0.0 {
            width = 1.0;
        }
        self.width = width;
        self.mask = nbuckets - 1;
        self.buckets = (0..nbuckets).map(|_| Vec::new()).collect();
        // one global descending sort, then appends keep every bucket
        // sorted; re-park the cursor at the earliest event's day
        entries.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
        self.cur_day = if lo.is_finite() { self.day_of(lo) } else { 0 };
        for e in entries {
            let idx = (self.day_of(e.at) as usize) & self.mask;
            self.buckets[idx].push(e);
        }
    }
}

/// The DES scheduler queue: push `(virtual time, event)`, pop in
/// ascending `(time, push order)`. Deterministic by construction for
/// either [`QueueKind`].
pub struct EventQueue<T> {
    seq: u64,
    imp: Impl<T>,
}

enum Impl<T> {
    Heap(BinaryHeap<Entry<T>>),
    Calendar(CalendarQueue<T>),
}

impl<T> EventQueue<T> {
    pub fn new(kind: QueueKind) -> Self {
        let imp = match kind {
            QueueKind::Heap => Impl::Heap(BinaryHeap::new()),
            QueueKind::Calendar => Impl::Calendar(CalendarQueue::new()),
        };
        EventQueue { seq: 0, imp }
    }

    pub fn push(&mut self, at: f64, item: T) {
        self.seq += 1;
        let e = Entry { at, seq: self.seq, item };
        match &mut self.imp {
            Impl::Heap(h) => h.push(e),
            Impl::Calendar(c) => c.push(e),
        }
    }

    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = match &mut self.imp {
            Impl::Heap(h) => h.pop(),
            Impl::Calendar(c) => c.pop(),
        }?;
        Some((e.at, e.item))
    }

    pub fn len(&self) -> usize {
        match &self.imp {
            Impl::Heap(h) => h.len(),
            Impl::Calendar(c) => c.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn time_key_preserves_order() {
        let samples = [
            0.0, 1e-300, 1e-9, 0.5, 1.0, 60.0, 86400.0, 1.2e7, 1e300, -0.0, -1.0, -1e9,
        ];
        for &a in &samples {
            for &b in &samples {
                assert_eq!(a < b, time_key(a) < time_key(b), "order flip at {a} vs {b}");
                assert_eq!(a == b || (a == 0.0 && b == 0.0), time_key(a) == time_key(b));
            }
        }
    }

    /// Drive both implementations through the same randomized
    /// push/pop schedule and demand the identical pop sequence —
    /// including FIFO order within equal-timestamp clusters.
    #[test]
    fn calendar_matches_heap_on_random_streams() {
        for seed in 0..8u64 {
            let mut rng = Rng::new(seed * 7919 + 1);
            let mut cal = EventQueue::new(QueueKind::Calendar);
            let mut heap = EventQueue::new(QueueKind::Heap);
            // DES-shaped stream: time only moves forward from the last
            // pop, pushes land at now + a mixed-scale delay, and every
            // 5th push reuses the previous timestamp to force ties
            let mut now = 0.0f64;
            let mut last_at = 0.0f64;
            let mut next_id = 0u64;
            for step in 0..4000 {
                let burst = rng.below(4) + 1;
                for k in 0..burst {
                    let at = if k % 5 == 4 {
                        last_at
                    } else {
                        let scale = match rng.below(3) {
                            0 => 1.0,
                            1 => 60.0,
                            _ => 86400.0,
                        };
                        now + rng.uniform(0.0, scale)
                    };
                    last_at = at.max(now);
                    cal.push(last_at, next_id);
                    heap.push(last_at, next_id);
                    next_id += 1;
                }
                if step % 3 != 0 {
                    let a = cal.pop();
                    let b = heap.pop();
                    assert_eq!(a, b, "pop #{step} diverged (seed {seed})");
                    if let Some((at, _)) = a {
                        assert!(at >= now, "time ran backwards");
                        now = at;
                    }
                }
            }
            // drain: the full remaining order must agree
            assert_eq!(cal.len(), heap.len());
            while let Some(a) = cal.pop() {
                assert_eq!(Some(a), heap.pop(), "drain diverged (seed {seed})");
            }
            assert!(heap.pop().is_none());
        }
    }

    #[test]
    fn equal_timestamps_pop_in_push_order() {
        let mut q = EventQueue::new(QueueKind::Calendar);
        for id in 0..100u64 {
            q.push(42.0, id);
        }
        for id in 0..100u64 {
            assert_eq!(q.pop(), Some((42.0, id)), "FIFO at equal times");
        }
    }

    #[test]
    fn sparse_and_clustered_times_survive_resizes() {
        let mut cal = EventQueue::new(QueueKind::Calendar);
        let mut heap = EventQueue::new(QueueKind::Heap);
        // clusters separated by huge gaps: exercises the direct-search
        // fallback and both grow and shrink rebuilds
        let mut id = 0u64;
        for cluster in 0..6 {
            let base = cluster as f64 * 1e7;
            for j in 0..700 {
                let at = base + (j % 97) as f64 * 0.001;
                cal.push(at, id);
                heap.push(at, id);
                id += 1;
            }
        }
        while let Some(a) = cal.pop() {
            assert_eq!(Some(a), heap.pop());
        }
        assert!(heap.pop().is_none());
    }
}
