//! Repo determinism lint: project invariants the compiler can't check.
//!
//! The quorum validator compares payload bytes across anonymous hosts,
//! so every code path that can influence a payload must be bit-identical
//! across platforms, thread counts and hash seeds. Three classes of
//! nondeterminism have bitten (or nearly bitten) this codebase and are
//! mechanically banned here, plus one safety invariant:
//!
//! * **`unordered-map`** — no `HashMap`/`HashSet` in payload-affecting
//!   modules (`gp/`, `boinc/exchange.rs`, `boinc/server.rs`,
//!   `boinc/events.rs`): iteration order depends on the hasher seed, so
//!   any fold/max/serialize over one is a nondeterminism bug waiting
//!   for a tie. Use `BTreeMap`/`BTreeSet`.
//! * **`wall-clock`** — no `Instant::now`/`SystemTime` in deterministic
//!   code paths (`gp/`, `sim/`, `coordinator/`, `boinc/` except
//!   `boinc/net.rs`): the simulator runs in virtual time and WU
//!   execution must be a pure function of the spec.
//! * **`float-arith`** — no transcendental float calls (`.sin(`,
//!   `.exp(`, `.ln(`, …) in `gp/`/`boinc/` outside the pinned kernels
//!   in `gp/tape.rs`: libm results vary by platform, so stray float
//!   math near the evaluation path risks the bit-identical contract.
//! * **`raw-print`** — no bare `println!`/`eprintln!` (or their
//!   non-newline forms) outside `util/log.rs`, `metrics/dashboard.rs`
//!   and `lint/` itself: stdout is reserved for report/dashboard output
//!   (route through [`crate::metrics::dashboard::emit`]) and stderr for
//!   the leveled log macros (`log_error!` … `log_trace!`), so `-v`/`-q`
//!   verbosity routing actually governs every diagnostic.
//! * **`core-mutation`** — no direct `Db` mutator calls
//!   (`.db.insert_wu(`, `.db.result_mut(`, …) in `boinc/` outside the
//!   pure core (`boinc/events.rs`) and `boinc/db.rs` itself: every
//!   state transition must flow through `events::apply` so the WAL
//!   captures it and crash replay reconstructs identical state. Shells
//!   may read the db freely; they mutate it only by dispatching events.
//! * **`legacy-metrics`** — no string-keyed metric reads
//!   (`.counter("…")`) or free-text `.dump()` anywhere: both were
//!   deleted in favor of the typed `Metrics::get(Counter::…)` /
//!   `MetricsSnapshot` surface, and this rule keeps them from
//!   reappearing (string keys silently read 0 on a typo; typed reads
//!   are compile errors).
//! * **`forbid-unsafe`** — `lib.rs` must carry
//!   `#![forbid(unsafe_code)]` and `main.rs` `#![deny(unsafe_code)]`:
//!   volunteer payloads are untrusted input.
//!
//! Escape hatches, for code that is deliberate and audited:
//! `// lint:allow(<rule>)` on the offending line or the line above
//! suppresses one finding; `// lint:allow-file(<rule>)` anywhere in a
//! file suppresses the rule for that file. Both should carry a short
//! rationale after a colon.
//!
//! Scanning is line-based and deliberately simple: `//` comments are
//! stripped before matching (so prose mentioning `HashMap` is fine),
//! and everything from the first `#[cfg(test)]` to end-of-file is
//! skipped — this repo keeps test modules at the tail of each file.
//!
//! Run as `vgp lint` (exit 1 on findings) or via `rust/tests/lint.rs`,
//! both of which gate CI's `static-analysis` job.

use std::path::Path;

/// One lint violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the scanned source root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub excerpt: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.excerpt)
    }
}

/// Every rule the linter knows, with the substring patterns it bans.
pub const RULES: &[(&str, &[&str])] = &[
    ("unordered-map", &["HashMap", "HashSet"]),
    ("wall-clock", &["Instant::now", "SystemTime"]),
    ("float-arith", &[".sin(", ".cos(", ".tan(", ".exp(", ".ln(", ".sqrt(", ".powf(", ".powi("]),
    ("raw-print", &["println!", "eprintln!", "print!(", "eprint!("]),
    (
        "core-mutation",
        &[
            ".db.insert_wu(",
            ".db.insert_result(",
            ".db.upsert_host(",
            ".db.wu_mut(",
            ".db.result_mut(",
            ".db.host_mut(",
            ".db.pop_unsent(",
            ".db.push_unsent(",
            ".db.mark_in_progress(",
            ".db.retire_in_progress(",
            ".db.take_expired(",
            ".db.mark_assimilated(",
            ".db.mark_too_many_errors(",
            ".db.mark_too_many_total(",
            ".db.mark_couldnt_send(",
        ],
    ),
    ("legacy-metrics", &[".counter(\"", ".dump()"]),
];

/// Does `rule` apply to the file at `rel` (root-relative, `/`-separated)?
fn in_scope(rule: &str, rel: &str) -> bool {
    match rule {
        "unordered-map" => {
            rel.starts_with("gp/")
                || rel == "boinc/exchange.rs"
                || rel == "boinc/server.rs"
                || rel == "boinc/events.rs"
                || rel == "boinc/daemon.rs"
                || rel == "boinc/transport.rs"
        }
        "wall-clock" => {
            rel.starts_with("gp/")
                || rel.starts_with("sim/")
                || rel.starts_with("coordinator/")
                || (rel.starts_with("boinc/") && rel != "boinc/net.rs")
        }
        "float-arith" => {
            (rel.starts_with("gp/") || rel.starts_with("boinc/")) && rel != "gp/tape.rs"
        }
        // the two print funnels and the linter itself (whose RULES table
        // spells the banned tokens) are the only places allowed to print
        "raw-print" => {
            rel != "util/log.rs" && rel != "metrics/dashboard.rs" && !rel.starts_with("lint/")
        }
        // the pure core owns all mutation; db.rs defines the mutators
        "core-mutation" => {
            rel.starts_with("boinc/") && rel != "boinc/events.rs" && rel != "boinc/db.rs"
        }
        // the linter's own RULES table spells the banned tokens
        "legacy-metrics" => !rel.starts_with("lint/"),
        _ => false,
    }
}

/// Lint one file's source text. Pure function — the engine behind both
/// [`lint_crate`] and the unit tests.
pub fn lint_source(rel: &str, content: &str) -> Vec<Finding> {
    let mut findings = Vec::new();

    // whole-file safety invariant
    if rel == "lib.rs" && !content.contains("#![forbid(unsafe_code)]") {
        findings.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: "forbid-unsafe",
            excerpt: "missing #![forbid(unsafe_code)]".to_string(),
        });
    }
    if rel == "main.rs"
        && !content.contains("#![deny(unsafe_code)]")
        && !content.contains("#![forbid(unsafe_code)]")
    {
        findings.push(Finding {
            file: rel.to_string(),
            line: 1,
            rule: "forbid-unsafe",
            excerpt: "missing #![deny(unsafe_code)]".to_string(),
        });
    }

    let active: Vec<&(&str, &[&str])> = RULES.iter().filter(|(r, _)| in_scope(r, rel)).collect();
    if active.is_empty() {
        return findings;
    }

    let mut prev_allows = String::new();
    for (idx, raw) in content.lines().enumerate() {
        // test modules tail their files in this repo; nothing after the
        // first #[cfg(test)] can affect payloads
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let code = raw.split("//").next().unwrap_or("");
        for (rule, patterns) in &active {
            if !patterns.iter().any(|p| code.contains(p)) {
                continue;
            }
            let file_allow = format!("lint:allow-file({rule})");
            let line_allow = format!("lint:allow({rule})");
            if content.contains(&file_allow)
                || raw.contains(&line_allow)
                || prev_allows.contains(&line_allow)
            {
                continue;
            }
            findings.push(Finding {
                file: rel.to_string(),
                line: idx + 1,
                rule,
                excerpt: raw.trim().to_string(),
            });
        }
        prev_allows = if raw.trim_start().starts_with("//") { raw.to_string() } else { String::new() };
    }
    findings
}

/// Recursively lint every `.rs` file under `src_root` (the crate's
/// `src/` directory). Files are visited in sorted order so output is
/// stable.
pub fn lint_crate(src_root: &Path) -> anyhow::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for rel in &files {
        let content = std::fs::read_to_string(src_root.join(rel))?;
        findings.extend(lint_source(rel, &content));
    }
    Ok(findings)
}

/// Number of `.rs` files that would be scanned (for reporting).
pub fn count_rs(src_root: &Path) -> anyhow::Result<usize> {
    let mut files = Vec::new();
    collect_rs(src_root, src_root, &mut files)?;
    Ok(files.len())
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> anyhow::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .expect("walked path is under root")
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_unordered_map_in_scope() {
        let f = lint_source("gp/foo.rs", "use std::collections::HashMap;\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unordered-map");
        assert_eq!(f[0].line, 1);
        // same text out of scope is clean
        assert!(lint_source("util/foo.rs", "use std::collections::HashMap;\n").is_empty());
    }

    #[test]
    fn comments_and_test_modules_are_skipped() {
        let src = "// HashMap is banned here, says this comment\nlet x = 1;\n#[cfg(test)]\nmod tests { use std::collections::HashMap; }\n";
        assert!(lint_source("gp/foo.rs", src).is_empty());
    }

    #[test]
    fn line_allow_suppresses_same_and_next_line() {
        let same = "let t = Instant::now(); // lint:allow(wall-clock): bench only\n";
        assert!(lint_source("coordinator/x.rs", same).is_empty());
        let above = "// lint:allow(wall-clock): bench only\nlet t = Instant::now();\n";
        assert!(lint_source("coordinator/x.rs", above).is_empty());
        let wrong_rule = "// lint:allow(unordered-map)\nlet t = Instant::now();\n";
        assert_eq!(lint_source("coordinator/x.rs", wrong_rule).len(), 1);
    }

    #[test]
    fn file_allow_suppresses_whole_file() {
        let src = "// lint:allow-file(float-arith): diagnostic bounds only\nlet a = x.exp();\nlet b = y.ln();\n";
        assert!(lint_source("gp/verify.rs", src).is_empty());
        let no_marker = "let a = x.exp();\nlet b = y.ln();\n";
        assert_eq!(lint_source("gp/verify.rs", no_marker).len(), 2);
    }

    #[test]
    fn tape_rs_is_the_pinned_kernel_exception() {
        assert!(lint_source("gp/tape.rs", "let s = x.sin();\n").is_empty());
        assert_eq!(lint_source("gp/eval.rs", "let s = x.sin();\n").len(), 1);
        assert!(lint_source("boinc/net.rs", "let t = Instant::now();\n").is_empty());
        assert_eq!(lint_source("boinc/client.rs", "let t = Instant::now();\n").len(), 1);
    }

    #[test]
    fn raw_print_funnels_are_exempt() {
        let src = "fn f() { println!(\"x\"); }\n";
        let main = format!("#![deny(unsafe_code)]\n{src}");
        assert_eq!(lint_source("main.rs", &main)[0].rule, "raw-print");
        assert_eq!(lint_source("gp/eval.rs", src).len(), 1);
        assert!(lint_source("util/log.rs", src).is_empty());
        assert!(lint_source("metrics/dashboard.rs", src).is_empty());
        assert!(lint_source("lint/mod.rs", src).is_empty());
        let stderr = "fn f() { eprintln!(\"x\"); }\n";
        assert_eq!(lint_source("sim/mod.rs", stderr)[0].rule, "raw-print");
        let allowed = "fn f() { println!(\"x\"); } // lint:allow(raw-print): demo\n";
        assert!(lint_source("sim/mod.rs", allowed).is_empty());
    }

    #[test]
    fn core_mutation_confined_to_pure_core() {
        let src = "let id = core.db.insert_wu(wu);\n";
        let f = lint_source("boinc/server.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "core-mutation");
        assert_eq!(lint_source("boinc/exchange.rs", "s.db.result_mut(rid);\n").len(), 1);
        // the pure core and the Db definition itself are the two homes
        assert!(lint_source("boinc/events.rs", src).is_empty());
        assert!(lint_source("boinc/db.rs", src).is_empty());
        // reads are always fine
        assert!(lint_source("boinc/server.rs", "let w = self.db.wu(id);\n").is_empty());
        // out of boinc/ the rule does not apply
        assert!(lint_source("metrics/snapshot.rs", src).is_empty());
        let allowed = "core.db.insert_wu(wu); // lint:allow(core-mutation): migration shim\n";
        assert!(lint_source("boinc/net.rs", allowed).is_empty());
    }

    #[test]
    fn legacy_metrics_surface_stays_dead() {
        let read = "let n = s.metrics.counter(\"result.valid\");\n";
        let f = lint_source("boinc/server.rs", read);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "legacy-metrics");
        assert_eq!(lint_source("metrics/mod.rs", "let s = m.dump();\n").len(), 1);
        // applies crate-wide, not just to boinc/
        assert_eq!(lint_source("coordinator/mod.rs", read).len(), 1);
        // typed reads are the sanctioned surface
        let typed = "let n = s.metrics.get(Counter::ResultValid);\n";
        assert!(lint_source("boinc/server.rs", typed).is_empty());
        // the linter itself (this RULES table) is exempt
        assert!(lint_source("lint/mod.rs", read).is_empty());
    }

    #[test]
    fn daemon_and_transport_are_in_determinism_scope() {
        let map = "use std::collections::HashMap;\n";
        assert_eq!(lint_source("boinc/daemon.rs", map)[0].rule, "unordered-map");
        assert_eq!(lint_source("boinc/transport.rs", map)[0].rule, "unordered-map");
        let clock = "let t = Instant::now();\n";
        assert_eq!(lint_source("boinc/daemon.rs", clock)[0].rule, "wall-clock");
        let mutator = "core.db.mark_assimilated(wu, canon);\n";
        assert_eq!(lint_source("boinc/daemon.rs", mutator)[0].rule, "core-mutation");
        assert!(lint_source("boinc/events.rs", mutator).is_empty());
    }

    #[test]
    fn forbid_unsafe_checks_crate_roots() {
        let f = lint_source("lib.rs", "pub mod gp;\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "forbid-unsafe");
        assert!(lint_source("lib.rs", "#![forbid(unsafe_code)]\npub mod gp;\n").is_empty());
        assert_eq!(lint_source("main.rs", "fn main() {}\n").len(), 1);
        assert!(lint_source("main.rs", "#![deny(unsafe_code)]\nfn main() {}\n").is_empty());
    }

    #[test]
    fn crate_tree_is_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_crate(&src).unwrap();
        assert!(
            findings.is_empty(),
            "lint findings:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
        assert!(count_rs(&src).unwrap() > 20);
    }
}
