//! Parameter-sweep example (the Commander-style use case of §1): the
//! cross product generations x population of an ant campaign, each cell
//! simulated on a 10-host lab pool, reported as a sweep table.

use vgp::churn::PoolParams;
use vgp::coordinator::{simulate_campaign, sweep};
use vgp::gp::problems::ProblemKind;
use vgp::sim::SimConfig;
use vgp::util::bench::Table;

fn main() {
    let campaigns = sweep("ant", ProblemKind::Ant, 25, &[500, 1000, 2000], &[1000, 2000]);
    let mut table = Table::new(&["campaign", "T_seq", "T_B", "Acc", "done"]);
    for c in &campaigns {
        let r = simulate_campaign(&c.clone(), &PoolParams::lab(10), &[("lab", 10)], SimConfig::default(), 11);
        table.row(&[
            c.name.clone(),
            format!("{:.0}s", r.t_seq),
            format!("{:.0}s", r.t_b),
            format!("{:.2}", r.acceleration),
            format!("{}/{}", r.completed, r.runs),
        ]);
    }
    println!("parameter sweep (ant, 25 runs per cell, 10 lab hosts):");
    table.print();
}
