//! Fig 2 driver: host churn over a month (the paper plots September
//! 2007). Emits an ASCII plot and a CSV (`churn_trace.csv`).

use vgp::churn::{churn_trace, sample_pool, PoolParams, FIG1_CITIES_MUX20};
use vgp::metrics::{ascii_plot, to_csv};
use vgp::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(2007);
    // model the paper's September-2007 pool: volunteers joining over
    // the month with limited lifetimes
    let mut params = PoolParams::volunteer(41);
    params.arrival_spread_days = 20.0;
    let hosts = sample_pool(&mut rng, &params, FIG1_CITIES_MUX20);
    let tr = churn_trace(&hosts, 30);

    println!("{}", ascii_plot("Fig 2 — active volunteer hosts per day (Sept 2007 model)", &tr.days, &tr.active_hosts, 12));

    let rows: Vec<Vec<f64>> = (0..tr.days.len())
        .map(|i| vec![tr.days[i], tr.active_hosts[i], tr.arrivals[i], tr.departures[i]])
        .collect();
    let csv = to_csv(&["day", "active_hosts", "arrivals", "departures"], &rows, Some("churn_trace.csv"))?;
    println!("wrote churn_trace.csv ({} rows)", csv.lines().count() - 1);

    let total_arrivals: f64 = tr.arrivals.iter().sum();
    println!("arrivals over window: {total_arrivals} / 41 hosts (host churn — Fig 2 shape)");
    Ok(())
}
