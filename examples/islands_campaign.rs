//! Island-model GP over the simulated volunteer pool: a 6-multiplexer
//! campaign split into 4 demes × 4 epochs with ring migration.
//!
//! Unlike the paper's run-level campaigns (`mux_campaign.rs`), every
//! work unit here is *executed for real* inside the DES — the server's
//! migration exchange needs actual checkpoints and emigrants to route
//! between epochs. Compare the merged best against the isolated
//! (no-migration) baseline the second half prints.
//!
//! Run: `cargo run --release --example islands_campaign`

use vgp::churn::PoolParams;
use vgp::coordinator::{simulate_island_campaign, IslandCampaign, IslandReport};
use vgp::gp::islands::Topology;
use vgp::gp::problems::ProblemKind;
use vgp::sim::SimConfig;

fn report(label: &str, r: &IslandReport) {
    let o = &r.outcome;
    println!(
        "{label:>9}: {}/{} WUs in T_B={:.0}s | {} releases, {} migrants, {} timeouts, {} cancelled",
        o.completed,
        o.total_wus,
        o.makespan,
        r.stats.released,
        r.stats.immigrants_delivered,
        r.stats.timeouts,
        r.stats.cancelled
    );
    match &r.best {
        Some(b) => println!(
            "{:>9}  best raw={} hits={} (deme {}, epoch {}, {} nodes)",
            "",
            b.raw,
            b.hits,
            b.deme,
            b.epoch,
            b.tree.len()
        ),
        None => println!("{:>9}  no validated payloads", ""),
    }
}

fn main() {
    let mut ring = IslandCampaign::new("mux6_islands", ProblemKind::Mux6, 4, 4, 8, 150);
    ring.migration_k = 3;
    ring.seed = 11;
    let pool = PoolParams::volunteer(12);
    let cities = [("volunteers", 12)];
    let r = simulate_island_campaign(&ring, &pool, &cities, SimConfig::default(), 7);
    report("ring", &r);

    // ablation: same demes, no migration — the exchange still gates
    // epochs on each deme's own checkpoint, but no genes move
    let mut isolated = ring.clone();
    isolated.name = "mux6_isolated".into();
    isolated.topology = Topology::Isolated;
    let r0 = simulate_island_campaign(&isolated, &pool, &cities, SimConfig::default(), 7);
    report("isolated", &r0);
}
