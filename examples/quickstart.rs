//! End-to-end driver (EXPERIMENTS.md §E2E): the full three-layer stack
//! on a real workload.
//!
//! * A real BOINC-style server (TCP, threads) hosts an 11-multiplexer
//!   campaign: 12 GP runs x 20 generations x 512 individuals.
//! * N real worker clients attach over TCP; each worker executes GP
//!   runs whose fitness evaluation goes through the **AOT-compiled XLA
//!   artifact** loaded via PJRT (Layer 1+2), i.e. python is never on
//!   the request path.
//! * The same campaign is then run sequentially on one "machine" (the
//!   paper's T_seq baseline) and the speedup (eq. 1) is reported, plus
//!   the best-fitness trajectory proving real GP progress.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::time::Instant;

use vgp::boinc::net::{serve, Connection, Worker};
use vgp::boinc::server::{ServerConfig, ServerCore};
use vgp::coordinator::{exec, Campaign};
use vgp::gp::problems::ProblemKind;
use vgp::runtime::Runtime;
use vgp::util::json::Json;

const WORKERS: usize = 4;
const RUNS: usize = 12;
const GENS: usize = 20;
const POP: usize = 512;

fn main() -> anyhow::Result<()> {
    println!("== vgp quickstart: distributed GP with artifact evaluation ==");

    // ---------- build the campaign
    let mut campaign = Campaign::new("qs_mux11", ProblemKind::Mux11, RUNS, GENS, POP);
    campaign.seed = 1000;

    // ---------- sequential baseline (one machine, native order)
    println!("[1/3] sequential baseline ({RUNS} runs of mux11 {GENS}x{POP}, artifact eval)...");
    let rt = Runtime::load("artifacts")?;
    let specs: Vec<Json> = (0..RUNS).map(|r| campaign.wu_spec(r)).collect();
    let t0 = Instant::now();
    let mut seq_best: Vec<f64> = Vec::new();
    for spec in &specs {
        let payload = exec::run_wu_artifact(&rt, spec)?;
        seq_best.push(payload.f64_of("best_raw")?);
    }
    let t_seq = t0.elapsed().as_secs_f64();
    println!("      T_seq = {:.1}s; best_raw per run: {:?}", t_seq, &seq_best);

    // ---------- distributed: real server + N workers over TCP
    println!("[2/3] distributed: {WORKERS} workers over TCP, same campaign...");
    let mut core = ServerCore::new(ServerConfig::default());
    for wu in campaign.workunits() {
        core.submit_wu(wu);
    }
    let key = core.key.clone();
    let handle = serve(core)?;
    let addr = handle.addr;
    // pre-warm: every worker compiles its PJRT runtime BEFORE the clock
    // starts (client install time, not campaign time), synchronized by
    // a barrier so T_B measures the distributed campaign itself
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(WORKERS + 1));
    let mut joins = Vec::new();
    for w in 0..WORKERS {
        let key = key.clone();
        let barrier = barrier.clone();
        joins.push(std::thread::spawn(move || {
            // each worker owns its own PJRT runtime (compile-once per
            // process lifetime; the artifact is the Method-2 payload)
            let rt = Runtime::load("artifacts").expect("artifacts; run `make artifacts`");
            let worker = Worker {
                name: format!("worker{w}"),
                city: ["Cáceres", "Badajoz", "Mérida", "Granada"][w % 4].to_string(),
                flops: 1.3e9,
                poll_interval: std::time::Duration::from_millis(50),
            };
            barrier.wait();
            let mut conn = Connection::connect(addr).expect("connect to server");
            worker.run(&mut conn, &key, &move |spec| exec::run_wu_artifact(&rt, spec))
        }));
    }
    barrier.wait();
    let t0 = Instant::now();
    let mut completed = 0u64;
    for j in joins {
        let report = j.join().expect("worker thread").expect("worker run");
        completed += report.completed;
    }
    let t_b = t0.elapsed().as_secs_f64();

    // ---------- report
    let (assimilated, best_traj) = {
        let svc = handle.service.lock().unwrap();
        let payloads: Vec<Json> =
            svc.core.assimilated().iter().map(|a| a.payload.clone()).collect();
        (svc.core.assimilated().len(), payloads)
    };
    handle.shutdown();

    println!("[3/3] results");
    let accel = t_seq / t_b;
    println!("      T_seq = {t_seq:.1}s   T_B = {t_b:.1}s   acceleration = {accel:.2}");
    println!("      workers completed {completed} WUs; server assimilated {assimilated}");
    let mut best = f64::INFINITY;
    let mut hits_best = 0u64;
    for p in &best_traj {
        let raw = p.f64_of("best_raw").unwrap_or(f64::INFINITY);
        if raw < best {
            best = raw;
            hits_best = p.u64_of("hits").unwrap_or(0);
        }
    }
    println!(
        "      best-of-campaign: raw={best} hits={hits_best}/2048 (11-mux, {GENS} gens x {POP} pop)"
    );
    assert_eq!(assimilated, RUNS, "campaign must complete");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores > 1 {
        assert!(accel > 1.0, "distributed must beat sequential given {cores} cores");
    } else {
        // single-core testbed: the distributed path can only measure
        // middleware overhead (the paper's short-task regime, eq. 1 < 1);
        // require the overhead to stay bounded
        println!(
            "      single-core testbed: acceleration {accel:.2} measures pure \
             middleware overhead (paper's 11-mux regime: A = 0.29)"
        );
        assert!(accel > 0.25, "middleware overhead out of bounds: {accel}");
    }
    println!("quickstart OK");
    Ok(())
}
