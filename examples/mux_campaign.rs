//! Table 2 driver: the ECJ multiplexer campaigns on the volunteer pool
//! (Method 2). 11-mux: 828 short runs — churn and overhead dominate, so
//! acceleration collapses below 1 (the paper's 0.29). 20-mux: 42 long
//! runs — acceleration recovers (paper: 1.95).

use vgp::churn::{PoolParams, FIG1_CITIES_MUX11, FIG1_CITIES_MUX20};
use vgp::coordinator::{simulate_campaign, Campaign};
use vgp::gp::problems::ProblemKind;
use vgp::sim::SimConfig;
use vgp::util::bench::Table;

fn main() {
    let mut table = Table::new(&[
        "campaign", "runs", "hosts", "T_seq(sim)", "T_B(sim)", "Acc(sim)", "Acc(paper)", "CP(sim)", "CP(paper)",
    ]);

    let mux11 = Campaign::new("11-mux 50G x 4000I", ProblemKind::Mux11, 828, 50, 4000);
    let r11 = simulate_campaign(
        &mux11,
        &PoolParams::volunteer(45),
        FIG1_CITIES_MUX11,
        SimConfig::default(),
        42,
    );
    table.row(&[
        r11.campaign.clone(),
        "828".into(),
        format!("{}/{}", r11.productive_hosts, r11.attached_hosts),
        format!("{:.0}s", r11.t_seq),
        format!("{:.0}s", r11.t_b),
        format!("{:.2}", r11.acceleration),
        "0.29".into(),
        format!("{:.0} GF", r11.cp_gflops),
        "80 GF".into(),
    ]);

    let mux20 = Campaign::new("20-mux 50G x 1000I", ProblemKind::Mux20, 42, 50, 1000);
    let r20 = simulate_campaign(
        &mux20,
        &PoolParams::volunteer(41),
        FIG1_CITIES_MUX20,
        SimConfig::default(),
        42,
    );
    table.row(&[
        r20.campaign.clone(),
        "42".into(),
        format!("{}/{}", r20.productive_hosts, r20.attached_hosts),
        format!("{:.0}s", r20.t_seq),
        format!("{:.0}s", r20.t_b),
        format!("{:.2}", r20.acceleration),
        "1.95".into(),
        format!("{:.0} GF", r20.cp_gflops),
        "23 GF".into(),
    ]);

    println!("Table 2 — ECJ multiplexer campaigns on volunteer pools:");
    table.print();
    println!("\nshape checks: Acc(11-mux) < 1 < Acc(20-mux); client errors occurred");
    println!("(paper: Java heap failures): {} / {}", r11.client_errors, r20.client_errors);
    assert!(r11.acceleration < r20.acceleration, "granularity ordering must hold");
}
