//! Table 3 driver: the interest-point GP system behind a virtualization
//! layer (Method 3) — 12 solutions on 10 Windows hosts; paper: 215 h
//! sequential vs 48 h, acceleration 4.48, CP 25.67 GFLOPS.

use vgp::churn::PoolParams;
use vgp::coordinator::{simulate_campaign, Campaign};
use vgp::gp::problems::ProblemKind;
use vgp::sim::SimConfig;
use vgp::util::bench::Table;

fn main() {
    let c = Campaign::new("ip_75g_75i", ProblemKind::InterestPoint, 12, 75, 75);
    let r = simulate_campaign(
        &c,
        &PoolParams::virtualized_lab(10),
        &[("windows-lab", 10)],
        SimConfig::default(),
        42,
    );
    let mut table = Table::new(&[
        "config", "T_seq(sim)", "T_B(sim)", "Acc(sim)", "Acc(paper)", "CP(sim)", "CP(paper)",
    ]);
    table.row(&[
        "75 Gen, 75 Ind, 12 solutions, 10 virtualized hosts".into(),
        format!("{:.0}h", r.t_seq / 3600.0),
        format!("{:.0}h", r.t_b / 3600.0),
        format!("{:.2}", r.acceleration),
        "4.48".into(),
        format!("{:.1} GF", r.cp_gflops),
        "25.67 GF".into(),
    ]);
    println!("Table 3 — interest-point GP under virtualization:");
    table.print();
    println!("\nshape check: ~4-5x on 10 dedicated hosts (virtualization eats ~15%).");
    assert!(r.acceleration > 3.0 && r.acceleration < 9.0);
}
