//! Table 1 driver: Lil-gp Artificial Ant on the Santa Fe trail, 25
//! runs, pools of 5 and 10 lab clients (Method 1, controlled
//! environment). Prints the paper-vs-measured table.

use vgp::churn::PoolParams;
use vgp::coordinator::{simulate_campaign, Campaign};
use vgp::gp::problems::ProblemKind;
use vgp::sim::SimConfig;
use vgp::util::bench::Table;

fn main() {
    // paper rows: (config, clients, paper T_seq, paper T_B, paper acc)
    let paper: &[(usize, usize, usize, &str, &str, &str)] = &[
        (1000, 2000, 5, "650s", "395s", "1.65"),
        (2000, 1000, 5, "9200s", "2356s", "3.90"),
        (2000, 1000, 10, "9200s", "1623s", "5.67"),
        (1000, 1000, 5, "-", "-", "-"),
        (1000, 1000, 10, "-", "-", "-"),
        (1000, 2000, 10, "-", "-", "-"),
    ];
    let mut table = Table::new(&[
        "config", "clients", "T_seq(sim)", "T_B(sim)", "Acc(sim)", "Acc(paper)",
    ]);
    for &(gens, pop, clients, _pts, _ptb, pacc) in paper {
        let c = Campaign::new(&format!("ant_g{gens}_p{pop}"), ProblemKind::Ant, 25, gens, pop);
        let r = simulate_campaign(
            &c,
            &PoolParams::lab(clients),
            &[("lab", clients)],
            SimConfig::default(),
            42,
        );
        table.row(&[
            format!("{gens} Gen, {pop} Ind"),
            clients.to_string(),
            format!("{:.0}s", r.t_seq),
            format!("{:.0}s", r.t_b),
            format!("{:.2}", r.acceleration),
            pacc.to_string(),
        ]);
    }
    println!("Table 1 — Lil-gp ant on lab pools (25 runs each):");
    table.print();
    println!("\nshape checks: acc grows with clients and with per-run length;");
    println!("10 clients on the long config should approach the paper's ~5.7x.");
}
